"""Whole-plan compilation: one jitted XLA program per query plan.

The op-by-op engine dispatches each plan node separately and host-syncs
between several of them (join uniqueness probes, group counts, scalar
subqueries).  This module instead lowers the *whole* optimized logical
plan into a single traced function over the base-table tensors and
``jax.jit``-compiles it, so a repeated query is one device launch.

Tracing needs static shapes, so every relation inside the program is a
fixed-capacity ``CTable``: payload tensors padded to a power-of-two row
capacity plus a traced valid-row count ``n`` (rows ``[0, n)`` are live,
in their original order).  Host-computed base-table value bounds travel
with each relation as trace-time constants, so composite keys pack into
single int64 codes with *static* spans: joins direct-address a dense
table when the code space fits (sort + ``searchsorted`` otherwise),
small group-by key spaces segment without sorting at all, and ORDER BY
scatters a rank bijection instead of lexsorting — the argsort/lexsort
primitives are several times slower than plain ``sort`` on the CPU XLA
backend, so the whole module is built to avoid them.

Compiled executables are cached keyed by a fingerprint of (plan
structure with literals replaced by parameter markers, per-table schema
+ dtypes + bucketed capacities + key-uniqueness verdicts), so repeated
parameterized queries — same shape, different literals — reuse the
executable with zero retraces.  Anything the tracer cannot express
(non-unique-side inner joins, float group keys, store-backed scans)
raises ``Unsupported`` and falls back to the op-by-op engine; the
verdict is negative-cached.  ``CONFIG.compiled`` picks the route:
``off`` | ``auto`` (size-gated) | ``force``.

Observability: ``STATS`` counts cache hits/misses/evictions/fallbacks
and records per-plan trace / compile / execute timings.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
import warnings
import weakref
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.config import CONFIG
from repro.core.expr import Expr, Value
from repro.core.frame import (
    INT,
    ColumnMeta,
    TensorFrame,
    _empty_tensor,
    _valid_name,
    float_dtype,
)

from .parser import (
    Boxed,
    SBin,
    SCol,
    SDate,
    SExtract,
    SFunc,
    SIn,
    SLike,
    SLit,
    SqlError,
    transform,
)
from .plan import (
    Aggregate,
    AttachScalar,
    Distinct,
    Filter,
    Join,
    Limit,
    Project,
    Scan,
    Shared,
    Sort,
    node_columns,
    walk_scans,
)
from .lower import to_expr
from .udf import active_udfs, plan_uses_udf

__all__ = [
    "STATS",
    "Unsupported",
    "clear_cache",
    "maybe_execute_compiled",
    "reset_stats",
]

CACHE_CAPACITY = 32

_BIG = np.int64(np.iinfo(np.int64).max // 4)


class Unsupported(Exception):
    """Plan construct the traced path cannot express; fall back to the
    op-by-op engine."""


# ----------------------------------------------------------------------
# observability
# ----------------------------------------------------------------------
def _fresh_stats() -> Dict:
    return {
        "hits": 0,  # executable reused from the plan cache
        "misses": 0,  # fingerprint not cached -> trace+compile attempt
        "evictions": 0,  # LRU capacity evictions
        "compiles": 0,  # successful trace+compile
        "fallbacks": 0,  # unsupported plan -> op-by-op engine
        "compile_failures": 0,  # unexpected trace/compile crashes
        "skipped_small": 0,  # auto mode: input under compiled_min_rows
        "plans": {},  # digest -> per-plan timing/shape record
    }


STATS = _fresh_stats()

_CACHE: "OrderedDict[str, _Entry]" = OrderedDict()
_NEGATIVE: Dict[str, str] = {}  # fingerprint -> unsupported reason

# Concurrency (the serving layer calls in from many threads): _LOCK
# guards every shared structure here — _CACHE / _NEGATIVE / STATS /
# _PREP — while _TRACE_LOCKS holds one lock per in-flight fingerprint
# so two threads first-compiling the *same* plan serialize (one traces,
# the other reuses the entry) without blocking compiles of *different*
# plans.  XLA executables are safe to invoke concurrently.
_LOCK = threading.RLock()
_TRACE_LOCKS: Dict[str, threading.Lock] = {}


def reset_stats() -> None:
    with _LOCK:
        STATS.clear()
        STATS.update(_fresh_stats())


def _stats_snapshot() -> Dict:
    with _LOCK:
        out = {k: v for k, v in STATS.items() if k != "plans"}
        out["plans"] = {d: dict(r) for d, r in STATS["plans"].items()}
    return out


obs.metrics.register_group("sql.compile", _stats_snapshot, reset_stats)


def clear_cache() -> None:
    with _LOCK:
        _CACHE.clear()
        _NEGATIVE.clear()
        _TRACE_LOCKS.clear()


def _pow2(n: int) -> int:
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


# ----------------------------------------------------------------------
# literal parameterization
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SParam:
    """Placeholder for a numeric/date literal in a parameterized plan.

    The plan fingerprint is computed over the *parameterized* tree, so
    two runs of the same query shape with different literals share one
    compiled executable; the literal values travel as runtime inputs."""

    index: int
    kind: str  # 'int' | 'float' | 'date'

    def render(self) -> str:
        return f"?{self.index}:{self.kind}"


@dataclasses.dataclass(eq=False)
class _ParamLit(Expr):
    """Core expression broadcasting one traced parameter scalar."""

    scalar: object
    kind: str

    def eval(self, frame: TensorFrame) -> Value:
        n = frame.nrows
        if self.kind == "float":
            return Value("num", jnp.full((n,), self.scalar, dtype=float_dtype()))
        if self.kind == "date":
            return Value("date", jnp.full((n,), self.scalar, dtype=INT))
        return Value("num", jnp.full((n,), self.scalar, dtype=INT))


class _BoundParam:
    """SQL-AST-side wrapper binding an SParam to a traced scalar;
    ``lower.to_expr`` dispatches on the ``to_core_expr`` hook."""

    __slots__ = ("scalar", "kind")

    def __init__(self, scalar, kind: str):
        self.scalar = scalar
        self.kind = kind

    def to_core_expr(self) -> Expr:
        return _ParamLit(self.scalar, self.kind)

    def render(self) -> str:
        return f"?bound:{self.kind}"


def _param_item(v, out):
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return _param_expr(v, out)
    if isinstance(v, tuple):
        return tuple(_param_item(x, out) for x in v)
    return v


def _param_expr(e, out: List[Tuple[str, object]]):
    """Replace numeric/date literals with SParam markers, collecting
    their values.  IN lists, LIKE patterns, and SUBSTRING bounds stay
    literal — the engine needs them static (LUTs, slices)."""
    if isinstance(e, SLit):
        v = e.value
        if isinstance(v, bool) or not isinstance(
            v, (int, float, np.integer, np.floating)
        ):
            return e
        kind = "float" if isinstance(v, (float, np.floating)) else "int"
        out.append((kind, v))
        return SParam(len(out) - 1, kind)
    if isinstance(e, SDate):
        out.append(("date", int(e.days)))
        return SParam(len(out) - 1, "date")
    if isinstance(e, (SIn, SLike)) or (
        isinstance(e, SFunc) and e.name == "substring"
    ):
        return e
    if not dataclasses.is_dataclass(e):
        return e
    changes = {}
    for f in dataclasses.fields(e):
        v = getattr(e, f.name)
        nv = _param_item(v, out)
        if nv != v:
            changes[f.name] = nv
    return dataclasses.replace(e, **changes) if changes else e


def parameterize(node):
    """plan -> (plan with SParam markers, [(kind, value), ...]).

    Traversal order is deterministic, so re-running on a fresh plan of
    the same shape yields values aligned with the cached executable's
    parameter slots."""
    out: List[Tuple[str, object]] = []
    shared: Dict[Shared, Shared] = {}
    return _param_plan(node, out, shared), out


def _param_plan(node, out, shared):
    if isinstance(node, Scan):
        return node
    if isinstance(node, Shared):
        # equal copies must stay equal (and collect their literals
        # once), so parameterize the subtree a single time
        got = shared.get(node)
        if got is None:
            got = Shared(_param_plan(node.child, out, shared))
            shared[node] = got
        return got
    if isinstance(node, Filter):
        return Filter(
            _param_plan(node.child, out, shared), _param_expr(node.pred, out)
        )
    if isinstance(node, Project):
        return Project(
            _param_plan(node.child, out, shared),
            tuple((n, _param_expr(e, out)) for n, e in node.outputs),
        )
    if isinstance(node, Aggregate):
        return Aggregate(
            _param_plan(node.child, out, shared),
            tuple((n, _param_expr(e, out)) for n, e in node.keys),
            tuple(
                (n, fn, None if e is None else _param_expr(e, out))
                for n, fn, e in node.aggs
            ),
        )
    if isinstance(node, Join):
        return dataclasses.replace(
            node,
            left=_param_plan(node.left, out, shared),
            right=_param_plan(node.right, out, shared),
        )
    if isinstance(node, (Sort, Limit, Distinct)):
        return dataclasses.replace(
            node, child=_param_plan(node.child, out, shared)
        )
    if isinstance(node, AttachScalar):
        return dataclasses.replace(
            node,
            child=_param_plan(node.child, out, shared),
            sub=Boxed(_param_plan(node.sub.v, out, shared)),
        )
    raise Unsupported(f"plan node {type(node).__name__}")


# ----------------------------------------------------------------------
# base-table preparation (host side, cached per frame)
# ----------------------------------------------------------------------
class _PrepTable:
    __slots__ = ("frame", "cap", "combos", "bounds", "pads")

    def __init__(self, frame: TensorFrame):
        self.frame = frame
        self.cap = _pow2(frame.nrows)
        # cached padded (itensor, ftensor, n) args — only used when the
        # backend ignores donation (CPU), where reuse is safe
        self.pads = None
        # tuple(sorted cols) -> bool uniqueness verdict (host-computed
        # once; part of the fingerprint since it drives join strategy)
        self.combos: Dict[Tuple[str, ...], bool] = {}
        # name -> (lo, hi) static value bounds for int/date columns,
        # span rounded up to a power of two so nearby datasets share a
        # fingerprint.  These are trace-time constants: they turn join
        # builds into direct addressing and group codes into dense
        # segment ids (dict/bool columns get bounds from their metadata
        # instead).  Part of the fingerprint — they shape the program.
        self.bounds: Dict[str, Tuple[int, int]] = {}
        if frame.nrows:
            for name, m in frame.columns.items():
                if m.kind not in ("int", "date") or name.startswith(
                    _valid_name("")
                ):
                    continue
                lo, hi = frame.int_bounds(name)
                self.bounds[name] = (lo, lo + _pow2(hi - lo + 1) - 1)


_PREP: "weakref.WeakKeyDictionary[TensorFrame, _PrepTable]" = (
    weakref.WeakKeyDictionary()
)


def _prep_table(src: TensorFrame) -> _PrepTable:
    with _LOCK:
        return _prep_table_locked(src)


def _prep_table_locked(src: TensorFrame) -> _PrepTable:
    got = _PREP.get(src)
    if got is not None:
        return got
    f = src.materialize()
    for name in list(f.offloaded):
        # offloaded strings become dictionary-code int columns so the
        # traced program never touches host arrays; codes/dictionary
        # are cached on the physical column, so this is cheap to redo
        codes, dictionary = f.offloaded[name].codes()
        f = f._append_int_column(name, codes, "dict", dictionary)
    f.materialize()
    got = _PrepTable(f)
    _PREP[src] = got
    return got


def _ensure_unique(prep: _PrepTable, cols: Tuple[str, ...]) -> bool:
    with _LOCK:
        return _ensure_unique_locked(prep, cols)


def _ensure_unique_locked(prep: _PrepTable, cols: Tuple[str, ...]) -> bool:
    key = tuple(sorted(cols))
    if key in prep.combos:
        return prep.combos[key]
    f = prep.frame
    ok = all(
        c in f.columns
        and f.columns[c].is_int_like()
        and _valid_name(c) not in f.columns
        for c in key
    )
    verdict = False
    if ok:
        hint = f.unique_hint(list(key))
        if hint is None:
            if f.nrows == 0:
                hint = True
            else:
                arrs = [np.asarray(f.col_values(c)) for c in key]
                if len(arrs) == 1:
                    hint = int(np.unique(arrs[0]).size) == f.nrows
                else:
                    hint = (
                        np.unique(np.stack(arrs, axis=1), axis=0).shape[0]
                        == f.nrows
                    )
            f.set_stats(list(key), unique=bool(hint))
        verdict = bool(hint)
    prep.combos[key] = verdict
    return verdict


def _table_sig(name: str, prep: _PrepTable) -> str:
    f = prep.frame
    cols = ",".join(
        f"{n}:{m.kind}:{m.slot}:"
        f"{0 if m.dictionary is None else id(m.dictionary)}"
        for n, m in f.columns.items()
    )
    combos = ";".join(
        f"{'+'.join(k)}={int(v)}" for k, v in sorted(prep.combos.items())
    )
    bounds = ";".join(
        f"{n}={lo}:{hi}" for n, (lo, hi) in sorted(prep.bounds.items())
    )
    return (
        f"{name}[cap={prep.cap},iw={f.itensor.shape[1]},"
        f"fw={f.ftensor.shape[1]}]({cols})u({combos})b({bounds})"
    )


# ----------------------------------------------------------------------
# uniqueness requests: which base-column combos drive join strategy
# ----------------------------------------------------------------------
def _base_cols(node, names: List[str]):
    """Map qualified column names through rename-only chains back to
    one Scan's (table, base columns); None when not resolvable."""
    if isinstance(node, Scan):
        strip = node.alias + "."
        out = []
        for n in names:
            if not n.startswith(strip):
                return None
            out.append(n[len(strip):])
        return node.table, tuple(out)
    if isinstance(node, (Filter, Sort, Limit, Distinct, Shared)):
        return _base_cols(node.child, names)
    if isinstance(node, AttachScalar):
        if node.name in names:
            return None
        return _base_cols(node.child, names)
    if isinstance(node, Project):
        m = {n: e for n, e in node.outputs}
        mapped = []
        for n in names:
            e = m.get(n)
            if not isinstance(e, SCol):
                return None
            mapped.append(e.internal)
        return _base_cols(node.child, mapped)
    if isinstance(node, Join):
        want = set(names)
        if want <= node_columns(node.left):
            return _base_cols(node.left, names)
        if node.how not in ("semi", "anti") and want <= node_columns(
            node.right
        ):
            return _base_cols(node.right, names)
        return None
    return None


def _collect_unique_requests(node, reqs: Dict[str, set]):
    if isinstance(node, Join):
        if node.how in ("inner", "left"):
            for side, keys in (
                (node.left, node.left_keys),
                (node.right, node.right_keys),
            ):
                got = _base_cols(side, list(keys))
                if got is not None:
                    reqs.setdefault(got[0], set()).add(got[1])
        _collect_unique_requests(node.left, reqs)
        _collect_unique_requests(node.right, reqs)
        return
    if isinstance(node, AttachScalar):
        _collect_unique_requests(node.child, reqs)
        _collect_unique_requests(node.sub.v, reqs)
        return
    child = getattr(node, "child", None)
    if child is not None:
        _collect_unique_requests(child, reqs)


# ----------------------------------------------------------------------
# traced relations
# ----------------------------------------------------------------------
class CTable:
    """Fixed-capacity traced relation: an eager in-trace TensorFrame
    whose first ``n`` rows (traced count) are live, in original order.

    ``unique`` holds column combos known unique over the live rows;
    ``bounds`` holds *static* per-column (lo, hi) value bounds seeded
    from host-computed base-table stats — they make key spans known at
    trace time, which turns sort-based joins into direct addressing
    and multi-key group codes into single packed integers."""

    __slots__ = ("frame", "n", "unique", "bounds", "mask", "fdeps", "dbound")

    def __init__(
        self, frame: TensorFrame, n, unique=(), bounds=None, mask=None,
        fdeps=None, dbound=None,
    ):
        self.frame = frame
        self.n = n  # traced live-row count (== sum(mask) when masked)
        self.unique = set(unique)
        self.bounds = dict(bounds or {})
        # None: rows [0, n) are live (contiguous).  Otherwise a traced
        # bool mask: live rows sit at their original positions and the
        # compaction (nonzero + full-width gather, the most expensive
        # shape-preserving ops on this backend) is deferred until an
        # operator truly needs contiguity (sort / limit / final output)
        self.mask = mask
        # functional dependencies: column -> the probe-key columns that
        # determine it (a unique-build join makes every build column a
        # function of the probe keys).  GROUP BY / DISTINCT drop
        # determined columns from their packed code, which is what lets
        # e.g. q3's 3-key grouping collapse to one bounded key
        self.fdeps: Dict[str, frozenset] = dict(fdeps or {})
        # static upper bound on the column's distinct live values (only
        # where tighter than cap): an inner probe against a unique build
        # side bounds the surviving probe keys by the build capacity.
        # GROUP BY shrinks its output capacity to the product of its
        # keys' bounds — which shrinks every operator downstream
        self.dbound: Dict[str, int] = dict(dbound or {})

    @property
    def cap(self) -> int:
        return self.frame.nrows

    @property
    def row_valid(self):
        if self.mask is not None:
            return self.mask
        return jnp.arange(self.cap, dtype=INT) < self.n


def _compact(ct: CTable) -> CTable:
    """Gather the live rows into [0, n) (no-op when already there)."""
    if ct.mask is None:
        return ct
    idx = jnp.nonzero(ct.mask, size=ct.cap, fill_value=0)[0]
    return _gather_rows(ct, idx, ct.n)


def _is_unique(ct: CTable, keys) -> bool:
    ks = set(keys)
    return any(u <= ks for u in ct.unique)


def _gather_rows(ct: CTable, idx, n, unique=None) -> CTable:
    f = ct.frame
    out = TensorFrame(
        f.itensor[idx], f.ftensor[idx], dict(f.columns), {}, int(idx.shape[0])
    )
    # row subsets keep value bounds (padding rows are masked everywhere)
    return CTable(
        out, n, ct.unique if unique is None else unique, ct.bounds,
        fdeps=ct.fdeps, dbound=ct.dbound,
    )


def _effective_keys(ct: CTable, names) -> List[str]:
    """Drop grouping columns functionally determined by other kept
    grouping columns — equality of the determinants already implies
    equality of the determined values row-to-row."""
    kept = set(names)
    out: List[str] = []
    for k in names:
        dep = ct.fdeps.get(k)
        if dep and dep <= (kept - {k}):
            kept.discard(k)
            continue
        out.append(k)
    return out or list(names)[:1]


def _masked_min(v, ok):
    return jnp.min(jnp.where(ok, v, _BIG))


def _masked_max(v, ok):
    return jnp.max(jnp.where(ok, v, -_BIG))


def _rank(v, n: int):
    """Equality-preserving codes in ``[0, n]``: each value's first
    position in its own sorted order.  sort+searchsorted is several
    times cheaper than argsort/lexsort on the CPU XLA backend, which is
    why every operator here range-compresses through this instead of
    sorting composite keys directly."""
    return jnp.searchsorted(jnp.sort(v), v)


def _expr_bounds(ct: CTable, e) -> Optional[Tuple[int, int]]:
    """Sound static (lo, hi) value bounds for an integer-valued scalar
    plan expression, or None.  Interval arithmetic over column bounds
    lets *computed* group / sort keys (q7-q9's EXTRACT(YEAR ...), price
    buckets, ...) keep trace-time spans, so they pack densely instead
    of forcing the rank path and a full-capacity aggregate output."""
    if isinstance(e, SCol):
        m = ct.frame.meta(e.internal) if ct.frame.has_column(e.internal) else None
        if m is not None and m.kind == "bool":
            return 0, 1
        return ct.bounds.get(e.internal)
    if isinstance(e, SLit):
        if isinstance(e.value, bool):
            return int(e.value), int(e.value)
        if isinstance(e.value, int):
            return e.value, e.value
        return None
    if isinstance(e, SDate):
        return e.days, e.days
    if isinstance(e, SExtract):
        if e.field == "month":
            return 1, 12
        if e.field == "day":
            return 1, 31
        b = _expr_bounds(ct, e.e)
        if b is None:
            return None
        # calendar year is monotone in epoch days
        def _year(days: int) -> int:
            return int(
                np.datetime64(int(days), "D").astype("datetime64[Y]").astype(int)
            ) + 1970
        return _year(b[0]), _year(b[1])
    if isinstance(e, SBin) and e.op in ("+", "-", "*"):
        a = _expr_bounds(ct, e.a)
        b = _expr_bounds(ct, e.b)
        if a is None or b is None:
            return None
        if e.op == "+":
            lo, hi = a[0] + b[0], a[1] + b[1]
        elif e.op == "-":
            lo, hi = a[0] - b[1], a[1] - b[0]
        else:
            cands = [a[0] * b[0], a[0] * b[1], a[1] * b[0], a[1] * b[1]]
            lo, hi = min(cands), max(cands)
        if abs(lo) > 1 << 62 or abs(hi) > 1 << 62:
            return None  # the real computation could overflow int64
        return lo, hi
    return None


def _static_span(ct: CTable, name: str) -> Optional[Tuple[int, int]]:
    """(lo, span) known at trace time, or None.  Dict codes span the
    dictionary, bools span {0,1}, int/date columns use the bucketed
    base-table bounds propagated through row-preserving operators."""
    m = ct.frame.meta(name)
    if m.kind == "dict" and m.dictionary is not None:
        return 0, max(int(m.dictionary.shape[0]), 1)
    if m.kind == "bool":
        return 0, 2
    b = ct.bounds.get(name)
    if b is not None:
        return b[0], max(b[1] - b[0] + 1, 1)
    return None


# packed composite codes must stay well inside int64 (and strictly
# below the _BIG padding sentinel)
_PACK_LIMIT = 1 << 59
# largest direct-address table a join build will scatter into
_DENSE_JOIN_LIMIT = 1 << 22
# largest dense group-id space (sort-free group-by)
_DENSE_GROUP_LIMIT = 1 << 20


# ----------------------------------------------------------------------
# traced operators
# ----------------------------------------------------------------------
def _c_filter(node: Filter, ct: CTable, ctx) -> CTable:
    expr = to_expr(ctx.bind(node.pred))
    mask = expr.eval_bool(ct.frame) & ct.row_valid
    # no compaction: rows keep their positions under a narrowed mask
    return CTable(
        ct.frame, jnp.sum(mask, dtype=INT), ct.unique, ct.bounds, mask,
        ct.fdeps, ct.dbound,
    )


def _int_key(ct: CTable, name: str):
    m = ct.frame.meta(name)
    if not m.is_int_like():
        raise Unsupported(f"non-integer join key {name} (kind={m.kind})")
    v = ct.frame.col_values(name)
    val = ct.frame.valid_array(name)
    ok = ct.row_valid if val is None else (ct.row_valid & val)
    return v, ok


def _joint_codes(lct: CTable, lkeys, rct: CTable, rkeys):
    """Composite key codes comparable across sides, plus a *static*
    bound ``S`` on the code space when every key has trace-time bounds
    (dict keys re-coded onto a merged dictionary when the two sides'
    dictionaries differ).  ``S`` is None when any key needed traced
    bounds; a static ``S`` small enough lets the join direct-address."""
    lcode = jnp.zeros((lct.cap,), dtype=INT)
    rcode = jnp.zeros((rct.cap,), dtype=INT)
    lok = lct.row_valid
    rok = rct.row_valid
    S: Optional[int] = 1
    for i, (lk, rk) in enumerate(zip(lkeys, rkeys)):
        lv, lo_ok = _int_key(lct, lk)
        rv, ro_ok = _int_key(rct, rk)
        lok = lok & lo_ok
        rok = rok & ro_ok
        lm, rm = lct.frame.meta(lk), rct.frame.meta(rk)
        if (lm.kind == "dict") != (rm.kind == "dict"):
            raise Unsupported(f"join key {lk}={rk} mixes dict and non-dict")
        lo = span = None
        if lm.kind == "dict" and lm.dictionary is not rm.dictionary:
            merged = np.union1d(
                lm.dictionary.astype("U"), rm.dictionary.astype("U")
            )
            llut = jnp.asarray(
                np.searchsorted(merged, lm.dictionary.astype("U")), dtype=INT
            )
            rlut = jnp.asarray(
                np.searchsorted(merged, rm.dictionary.astype("U")), dtype=INT
            )
            lv = llut[jnp.clip(lv, 0, lm.dictionary.shape[0] - 1)]
            rv = rlut[jnp.clip(rv, 0, rm.dictionary.shape[0] - 1)]
            lo, span = 0, max(int(merged.shape[0]), 1)
        else:
            ls = _static_span(lct, lk)
            rs = _static_span(rct, rk)
            if ls is not None and rs is not None:
                lo = min(ls[0], rs[0])
                span = max(ls[0] + ls[1], rs[0] + rs[1]) - lo
        if i and (S is None or (span is not None and S * span > _PACK_LIMIT)):
            # packing could overflow int64 (previous key had only
            # traced bounds, or the static product got too wide):
            # rank-compress the running codes *jointly* so both sides
            # stay comparable.  Rank output is statically bounded by
            # the total capacity, so S recovers a static value.
            cat = jnp.concatenate([lcode, rcode])
            rr = _rank(cat, lct.cap + rct.cap)
            lcode, rcode = rr[: lct.cap], rr[lct.cap:]
            S = lct.cap + rct.cap + 1
        if span is None:
            tlo = jnp.minimum(_masked_min(lv, lok), _masked_min(rv, rok))
            thi = jnp.maximum(_masked_max(lv, lok), _masked_max(rv, rok))
            tspan = jnp.maximum(thi - tlo + 1, 1)
            lcode = lcode * tspan + jnp.clip(lv - tlo, 0, tspan - 1)
            rcode = rcode * tspan + jnp.clip(rv - tlo, 0, tspan - 1)
            S = None
        else:
            lcode = lcode * span + jnp.clip(lv - lo, 0, span - 1)
            rcode = rcode * span + jnp.clip(rv - lo, 0, span - 1)
            S = None if S is None else S * span
    return lcode, lok, rcode, rok, S


def _dense_lookup(code, ok, cap: int, S: int):
    """Direct-address table: slot ``c`` holds the (last) row whose key
    code is ``c``, or -1.  Exact — no post-probe code comparison."""
    return (
        jnp.full((S,), -1, dtype=INT)
        .at[jnp.where(ok, code, S)]
        .set(jnp.arange(cap, dtype=INT), mode="drop")
    )


def _stack_sides(lf: TensorFrame, l_idx, rf: TensorFrame, r_idx, cap: int):
    """Horizontal stack of gathered left and right payloads (left
    columns first, like the engine's join output).  A ``None`` index
    keeps that side's rows in place — no gather at all."""
    if set(lf.columns) & set(rf.columns):
        raise Unsupported("join sides share column names")
    lit_ = lf.itensor if l_idx is None else lf.itensor[l_idx]
    lft_ = lf.ftensor if l_idx is None else lf.ftensor[l_idx]
    rit_ = rf.itensor if r_idx is None else rf.itensor[r_idx]
    rft_ = rf.ftensor if r_idx is None else rf.ftensor[r_idx]
    it = jnp.concatenate([lit_, rit_], axis=1)
    ft = jnp.concatenate([lft_, rft_], axis=1)
    iw, fw = lf.itensor.shape[1], lf.ftensor.shape[1]
    cols: Dict[str, ColumnMeta] = {}
    for name, m in lf.columns.items():
        cols[name] = dataclasses.replace(m)
    for name, m in rf.columns.items():
        off = fw if m.kind == "float" else iw
        cols[name] = dataclasses.replace(m, slot=m.slot + off)
    return TensorFrame(it, ft, cols, {}, cap)


def _probe_build(build: CTable, bcode, bok, pcode, pok, S):
    """(matched, brow): for each probe row, whether a build row with an
    equal key exists and (any) one such row's index.  Direct addressing
    when the static code space fits; else sort + binary search."""
    if S is not None and S <= _DENSE_JOIN_LIMIT:
        tbl = _dense_lookup(bcode, bok, build.cap, S)
        brow = tbl[jnp.clip(pcode, 0, S - 1)]
        matched = (brow >= 0) & pok
        return matched, jnp.clip(brow, 0, build.cap - 1)
    key = jnp.where(bok, bcode, _BIG)
    s = jnp.sort(key)
    pos = jnp.searchsorted(s, pcode)
    posc = jnp.clip(pos, 0, build.cap - 1)
    matched = (pos < build.cap) & (s[posc] == pcode) & pok
    # recover the row index behind sorted slot ``posc``: rank the same
    # codes against the sort and scatter the row ids (live build codes
    # are unique, so ranks are collision-free where it matters)
    rank = jnp.searchsorted(s, key)
    perm = (
        jnp.zeros((build.cap,), dtype=INT)
        .at[rank]
        .set(jnp.arange(build.cap, dtype=INT))
    )
    return matched, perm[posc]


def _c_join(node: Join, lct: CTable, rct: CTable) -> CTable:
    lcode, lok, rcode, rok, S = _joint_codes(
        lct, node.left_keys, rct, node.right_keys
    )
    if node.how in ("semi", "anti"):
        # membership only
        if S is not None and S <= _DENSE_JOIN_LIMIT:
            tbl = _dense_lookup(rcode, rok, rct.cap, S)
            present = (tbl[jnp.clip(lcode, 0, S - 1)] >= 0) & lok
        else:
            s = jnp.sort(jnp.where(rok, rcode, _BIG))
            pos = jnp.searchsorted(s, lcode)
            posc = jnp.clip(pos, 0, rct.cap - 1)
            present = (pos < rct.cap) & (s[posc] == lcode) & lok
        keep = present if node.how == "semi" else (lct.row_valid & ~present)
        return CTable(
            lct.frame, jnp.sum(keep, dtype=INT), lct.unique, lct.bounds,
            keep, lct.fdeps, lct.dbound,
        )
    if node.how not in ("inner", "left"):
        raise Unsupported(f"join type {node.how}")

    right_build = _is_unique(rct, node.right_keys)
    if node.how == "left":
        if not right_build:
            if _is_unique(lct, node.left_keys):
                # one-to-many: expand matches instead of probing
                return _c_left_expand(
                    node, lct, lcode, lok, rct, rcode, rok, S
                )
            raise Unsupported("left join with no provably-unique side")
    elif not right_build and not _is_unique(lct, node.left_keys):
        raise Unsupported("inner join with no provably-unique side")
    if right_build:
        build, probe = rct, lct
        bcode, bok, pcode, pok = rcode, rok, lcode, lok
        pkeys = node.left_keys
    else:  # swapped: build on the unique left side, probe the right
        build, probe = lct, rct
        bcode, bok, pcode, pok = lcode, lok, rcode, rok
        pkeys = node.right_keys

    matched, brow = _probe_build(build, bcode, bok, pcode, pok, S)

    unique = set(probe.unique)
    if _is_unique(probe, pkeys):
        unique |= build.unique
    bounds = {**lct.bounds, **rct.bounds}
    # rows agreeing on the probe keys read the same (unique) build row,
    # so every build column is now a function of the probe keys
    fdeps = {**lct.fdeps, **rct.fdeps}
    dep = frozenset(pkeys)
    dbound = {**lct.dbound, **rct.dbound}
    for cname in build.frame.columns:
        fdeps[cname] = dep
        # build payloads are gathered from <= build.cap rows
        dbound[cname] = min(dbound.get(cname, build.cap), build.cap)

    if node.how == "inner":
        # surviving probe keys are a subset of the build side's live
        # key tuples, of which there are at most build.cap
        for pk in pkeys:
            dbound[pk] = min(dbound.get(pk, probe.cap), build.cap)
        # probe rows stay in place (the match flag becomes the mask);
        # only the build side pays a gather
        n_out = jnp.sum(matched, dtype=INT)
        if right_build:
            out = _stack_sides(probe.frame, None, build.frame, brow, probe.cap)
        else:
            out = _stack_sides(build.frame, brow, probe.frame, None, probe.cap)
        return CTable(out, n_out, unique, bounds, matched, fdeps, dbound)

    # left join: keep every probe (=left) row; unmatched rows take
    # clamped build payloads masked off by fresh validity columns
    out = _stack_sides(probe.frame, None, build.frame, brow, probe.cap)
    out = _mask_right(out, build.frame, matched)
    return CTable(out, probe.n, unique, bounds, probe.mask, fdeps, dbound)


def _mask_right(out: TensorFrame, rf: TensorFrame, matched) -> TensorFrame:
    """Append/merge validity for every right-side output column so
    unmatched left rows read as NULL (mirrors the engine's left-outer
    ``need_valid`` append)."""
    for name in list(rf.columns):
        if name.startswith(_valid_name("")):
            continue
        vn = _valid_name(name)
        if vn in out.columns:
            flag = (out.col_values(vn) != 0) & matched
        else:
            flag = matched
        out = out._append_int_column(vn, flag.astype(INT), "bool")
    return out


def _c_left_expand(node, lct, lcode, lok, rct, rcode, rok, S) -> CTable:
    """One-to-many left join with a provably-unique LEFT side: each
    right row finds its single left owner, producing the matched pairs;
    left rows no right row claimed are appended with NULL right
    payloads.  Output capacity = cap_right + cap_left."""
    matched_r, lrow_r = _probe_build(lct, lcode, lok, rcode, rok, S)

    # matched pairs, compacted over the right capacity
    idx_r = jnp.nonzero(matched_r, size=rct.cap, fill_value=0)[0]
    n1 = jnp.sum(matched_r, dtype=INT)
    # left rows never claimed (null-key left rows stay too: engine left
    # joins keep them with NULL right payloads)
    hit = (
        jnp.zeros((lct.cap,), dtype=INT)
        .at[lrow_r]
        .max(matched_r.astype(INT))
    )
    keep_l = lct.row_valid & (hit == 0)
    idx_l = jnp.nonzero(keep_l, size=lct.cap, fill_value=0)[0]
    n2 = jnp.sum(keep_l, dtype=INT)

    live = jnp.concatenate(
        [
            jnp.arange(rct.cap, dtype=INT) < n1,
            jnp.arange(lct.cap, dtype=INT) < n2,
        ]
    )
    # compact both parts into contiguous [0, n1+n2)
    sel = jnp.nonzero(live, size=rct.cap + lct.cap, fill_value=0)[0]
    l_all = jnp.concatenate([lrow_r[idx_r], idx_l])[sel]
    r_all = jnp.concatenate([idx_r, jnp.zeros((lct.cap,), dtype=INT)])[sel]
    matched_all = jnp.concatenate(
        [jnp.ones((rct.cap,), dtype=bool), jnp.zeros((lct.cap,), dtype=bool)]
    )[sel]
    out = _stack_sides(lct.frame, l_all, rct.frame, r_all, rct.cap + lct.cap)
    out = _mask_right(out, rct.frame, matched_all)
    return CTable(
        out, n1 + n2, set(), {**lct.bounds, **rct.bounds},
        dbound={**lct.dbound, **rct.dbound},
    )


def _check_group_cols(f: TensorFrame, names) -> None:
    for k in names:
        if f.valid_array(k) is not None:
            raise Unsupported(f"nullable group key {k}")
        if f.meta(k).kind == "obj":
            raise Unsupported(f"group key {k} is offloaded")


def _pack_group_code(ct: CTable, f: TensorFrame, names) -> Tuple:
    """(code, S): one int64 composite code per row whose equality
    matches tuple-equality of the named columns, and a static bound on
    the code space.  Keys with trace-time spans pack directly; float or
    unbounded keys are rank-compressed first (rank preserves equality),
    so S always stays static."""
    _check_group_cols(f, names)
    cap = ct.cap
    code = jnp.zeros((cap,), dtype=INT)
    S = 1
    for k in names:
        m = f.meta(k)
        v = f.col_values(k)
        sp = None if m.kind == "float" else _static_span(ct, k)
        if sp is None:
            if m.kind == "float":
                # collapse -0.0 onto +0.0 so equal keys share a rank
                v = jnp.where(v == 0, jnp.zeros((), dtype=v.dtype), v)
            v = _rank(v, cap)
            span = cap + 1
        else:
            lo, span = sp
            v = jnp.clip(v - lo, 0, span - 1)
        if S * span > _PACK_LIMIT:
            # re-rank the running code (injective on present values)
            code = _rank(code, cap)
            S = cap + 1
        code = code * span + v
        S = S * span
    return code, S


def _dbound_product(ct: CTable, names) -> int:
    """Static upper bound on the number of distinct live key tuples:
    the product of the per-key distinct bounds, saturating at cap."""
    db = 1
    for k in names:
        db *= ct.dbound.get(k, ct.cap)
        if db >= ct.cap:
            return ct.cap
    return db


def _group_ids(code, S: int, rv, cap: int, dmax: Optional[int] = None):
    """(gids, n_groups, cap_out): dense group ids in first-seen-code
    order for live rows; padding maps to ``cap_out`` so segment
    scatters drop it.  A small static code space counts occupancy
    directly (no sort at all); otherwise sort the codes once and rank
    against the distinct values.  ``dmax`` (a sound static bound on the
    distinct key count) shrinks the output capacity below the code
    space — the whole plan downstream of the aggregate narrows with
    it."""
    cap_out = min(cap, _pow2(S))
    if dmax is not None:
        cap_out = min(cap_out, _pow2(max(dmax, 1)))
    if S <= _DENSE_GROUP_LIMIT:
        ids = jnp.where(rv, code, S)
        cnt = jax.ops.segment_sum(
            jnp.ones((cap,), dtype=INT), ids, num_segments=S
        )
        present = cnt > 0
        dense = jnp.cumsum(present.astype(INT)) - 1
        n_groups = jnp.sum(present, dtype=INT)
        gids = jnp.where(rv, dense[jnp.clip(code, 0, S - 1)], cap_out)
        return gids, n_groups, cap_out
    scode = jnp.where(rv, code, _BIG)
    s = jnp.sort(scode)
    live = s < _BIG
    first = jnp.concatenate([live[:1], (s[1:] != s[:-1]) & live[1:]])
    n_groups = jnp.sum(first, dtype=INT)
    # dense id per sorted slot; a live row reads it back through the
    # first-occurrence slot of its own code
    did = jnp.cumsum(first.astype(INT)) - 1
    gids = jnp.where(rv, did[jnp.searchsorted(s, scode)], cap_out)
    return gids, n_groups, cap_out


def _c_aggregate(node: Aggregate, ct: CTable, ctx) -> CTable:
    f = ct.frame
    key_names: List[str] = []
    kbounds = dict(ct.bounds)
    for name, e in node.keys:
        if not (
            isinstance(e, SCol) and e.internal == name and f.has_column(name)
        ):
            f = f.with_column(name, to_expr(ctx.bind(e)))
            kb = _expr_bounds(ct, e)
            if kb is not None:
                kbounds[name] = kb
        key_names.append(name)
    specs: List[Tuple[str, str, Optional[str]]] = []
    for name, fn, e in node.aggs:
        if fn == "size":
            specs.append((name, fn, None))
            continue
        if isinstance(e, SCol) and f.has_column(e.internal):
            specs.append((name, fn, e.internal))
        else:
            cn = f"__in.{name}"
            f = f.with_column(cn, to_expr(ctx.bind(e)))
            specs.append((name, fn, cn))
    ct = CTable(f, ct.n, ct.unique, kbounds, ct.mask, ct.fdeps, ct.dbound)
    cap, rv = ct.cap, ct.row_valid
    fd = float_dtype()

    if key_names:
        _check_group_cols(f, key_names)
        eff = _effective_keys(ct, key_names)
        code, S = _pack_group_code(ct, f, eff)
        gids, n_groups, cap_out = _group_ids(
            code, S, rv, cap, _dbound_product(ct, eff)
        )
        rep = jax.ops.segment_min(
            jnp.where(rv, jnp.arange(cap, dtype=INT), _BIG),
            gids,
            num_segments=cap_out,
        )
        repc = jnp.clip(rep, 0, cap - 1)
    else:
        cap_out = 1
        n_groups = jnp.asarray(1, dtype=INT)
        gids = jnp.where(rv, 0, 1)
        repc = None

    icols: List = []
    fcols: List = []
    cols: Dict[str, ColumnMeta] = {}

    def add(name: str, kind: str, arr, dictionary=None):
        if kind == "float":
            cols[name] = ColumnMeta(name, "float", len(fcols))
            fcols.append(arr.astype(fd))
        else:
            cols[name] = ColumnMeta(name, kind, len(icols), dictionary)
            icols.append(arr.astype(INT))

    for k in key_names:
        m = f.meta(k)
        add(k, m.kind, f.col_values(k)[repc], m.dictionary)

    for name, fn, cn in specs:
        if fn == "size":
            add(name, "int", _seg_sum(rv.astype(INT), gids, cap_out))
            continue
        m = f.meta(cn)
        if m.kind == "obj":
            raise Unsupported(f"aggregate over offloaded column {cn}")
        v = f.col_values(cn)
        val = f.valid_array(cn)
        ok = rv if val is None else (rv & val)
        isf = m.kind == "float"
        if fn == "count":
            add(name, "int", _seg_sum(ok.astype(INT), gids, cap_out))
        elif fn == "sum":
            zero = jnp.zeros((), dtype=v.dtype)
            arr = _seg_sum(jnp.where(ok, v, zero), gids, cap_out)
            add(name, "float" if isf else "int", arr)
        elif fn == "mean":
            s_ = _seg_sum(jnp.where(ok, v, 0).astype(fd), gids, cap_out)
            c_ = _seg_sum(ok.astype(INT), gids, cap_out)
            # engine formula (agg.segment_agg): sum / max(count, 1)
            add(name, "float", s_ / jnp.maximum(c_, 1).astype(fd))
        elif fn in ("min", "max"):
            if fn == "min":
                sent = jnp.asarray(np.inf if isf else _BIG, dtype=v.dtype)
                arr = jax.ops.segment_min(
                    jnp.where(ok, v, sent), gids, num_segments=cap_out
                )
            else:
                sent = jnp.asarray(-np.inf if isf else -_BIG, dtype=v.dtype)
                arr = jax.ops.segment_max(
                    jnp.where(ok, v, sent), gids, num_segments=cap_out
                )
            add(name, m.kind, arr, m.dictionary)
        elif fn == "nunique":
            if isf:
                raise Unsupported("nunique over float column")
            add(name, "int", _seg_nunique(v, ok, gids, cap_out, cap))
        else:
            raise Unsupported(f"aggregate fn {fn}")

    it = jnp.stack(icols, axis=1) if icols else _empty_tensor(cap_out, INT)
    ft = jnp.stack(fcols, axis=1) if fcols else _empty_tensor(cap_out, fd)
    out = TensorFrame(it, ft, cols, {}, cap_out)
    unique = {frozenset(key_names)} if key_names else set()
    bounds = {k: ct.bounds[k] for k in key_names if k in ct.bounds}
    dbound = {k: ct.dbound[k] for k in key_names if k in ct.dbound}
    for name, fn, cn in specs:
        if fn in ("min", "max") and cn in ct.bounds:
            bounds[name] = ct.bounds[cn]  # output values c input values
    return CTable(out, n_groups, unique, bounds, dbound=dbound)


def _seg_sum(vals, gids, m: int):
    return jax.ops.segment_sum(vals, gids, num_segments=m)


def _seg_nunique(v, ok, gids, cap_out: int, cap: int):
    """COUNT(DISTINCT col) per group: pack (gid, rank(value)) into one
    code, sort it once, count first occurrences per gid — the traced
    twin of agg._segment_nunique, minus the host sync and the lexsort."""
    M = 2 * cap  # rank(v) <= cap < M, so the packing is collision-free
    pair = gids * M + _rank(v, cap)
    s = jnp.sort(jnp.where(ok, pair, _BIG))
    live = s < _BIG
    first = jnp.concatenate([live[:1], (s[1:] != s[:-1]) & live[1:]])
    seg = jnp.where(live, s // M, cap_out)
    return _seg_sum(first.astype(INT), seg, cap_out)


def _c_project(node: Project, ct: CTable, ctx) -> CTable:
    f = ct.frame
    srcs: List[str] = []
    mapping: Dict[str, str] = {}
    used = set()
    ebounds: Dict[str, Tuple[int, int]] = {}
    for i, (name, e) in enumerate(node.outputs):
        if (
            isinstance(e, SCol)
            and f.has_column(e.internal)
            and e.internal not in used
        ):
            src = e.internal
        else:
            src = f"__o.{i}.{name}"
            f = f.with_column(src, to_expr(ctx.bind(e)))
            eb = _expr_bounds(ct, e)
            if eb is not None:
                ebounds[name] = eb
        used.add(src)
        srcs.append(src)
        mapping[src] = name
    out = f.select(srcs).rename(mapping)
    unique = set()
    for combo in ct.unique:
        if all(c in mapping for c in combo):
            unique.add(frozenset(mapping[c] for c in combo))
    bounds = {
        name: ct.bounds[src]
        for src, name in mapping.items()
        if src in ct.bounds
    }
    bounds.update(ebounds)
    fdeps = {}
    for src, name in mapping.items():
        dep = ct.fdeps.get(src)
        if dep is not None and dep <= set(mapping):
            fdeps[name] = frozenset(mapping[d] for d in dep)
    dbound = {
        name: ct.dbound[src]
        for src, name in mapping.items()
        if src in ct.dbound
    }
    return CTable(out, ct.n, unique, bounds, ct.mask, fdeps, dbound)


def _order_code(node: Sort, ct: CTable):
    """One int64 per row whose ascending order IS the requested sort:
    keys pack least-significant first (static spans multiply in; float
    or unbounded keys enter through their order-preserving rank),
    seeded with the row index so codes are *distinct* per row and ties
    break stably; dead rows land after every live row."""
    f = ct.frame
    cap = ct.cap
    acc = jnp.arange(cap, dtype=INT)  # stable tiebreak, keeps acc distinct
    S = cap
    for name, asc in reversed(node.keys):  # first key most significant
        m = f.meta(name)
        v = f.col_values(name)
        if not asc:
            v = -v
        sp = None if m.kind == "float" else _static_span(ct, name)
        if sp is None:
            r = _rank(v, cap)  # order-preserving (ties collapse: fine)
            lo, span = 0, cap + 1
        else:
            lo, span = sp
            if not asc:
                lo = -(lo + span - 1)  # negation flips the window
            r = jnp.clip(v - lo, 0, span - 1)
        if S * span > _PACK_LIMIT:
            acc = _rank(acc, cap)  # bijective on a distinct array
            S = cap
        acc = r * S + acc
        S = S * span
    return jnp.where(ct.row_valid, acc, acc + S)  # padding rows last


def _c_sort(node: Sort, ct: CTable) -> CTable:
    """ORDER BY without a lexsort: ranking the distinct packed order
    codes is a bijection, so scattering the ranks yields the sort
    permutation from two cheap sorts."""
    cap = ct.cap
    acc = _order_code(node, ct)
    pos = _rank(acc, cap)  # bijection: every acc value is distinct
    order = jnp.zeros((cap,), dtype=INT).at[pos].set(jnp.arange(cap, dtype=INT))
    return _gather_rows(ct, order, ct.n)


def _c_topk(sort_node: Sort, k: int, ct: CTable) -> CTable:
    """Fused ORDER BY + LIMIT k: ``top_k`` over the negated order
    codes finds the k smallest (ties to the lower row index, matching
    the stable sort), so only k rows are ever gathered."""
    kk = min(ct.cap, _pow2(k))
    _, idx = jax.lax.top_k(-_order_code(sort_node, ct), kk)
    out = _gather_rows(ct, idx, jnp.minimum(ct.n, k))
    return out


def _c_limit(node: Limit, ct: CTable) -> CTable:
    k = int(node.n)
    ct = _compact(ct)  # LIMIT slices, so rows must sit in [0, n)
    new_cap = min(ct.cap, _pow2(k))
    f = ct.frame
    out = TensorFrame(
        f.itensor[:new_cap], f.ftensor[:new_cap], dict(f.columns), {}, new_cap
    )
    return CTable(
        out, jnp.minimum(ct.n, k), ct.unique, ct.bounds, fdeps=ct.fdeps,
        dbound=ct.dbound,
    )


def _c_distinct(ct: CTable) -> CTable:
    f = ct.frame
    names = f.column_names
    for c in names:
        if not f.meta(c).is_int_like():
            raise Unsupported(
                f"DISTINCT over kind {f.meta(c).kind} column {c}"
            )
    rv = ct.row_valid
    _check_group_cols(f, names)
    eff = _effective_keys(ct, names)
    code, S = _pack_group_code(ct, f, eff)
    gids, n_out, cap_out = _group_ids(
        code, S, rv, ct.cap, _dbound_product(ct, eff)
    )
    # each group's first row index; sorting puts the kept rows in
    # original order (matches the engine) with empty slots pushed last
    rep = jax.ops.segment_min(
        jnp.where(rv, jnp.arange(ct.cap, dtype=INT), _BIG),
        gids,
        num_segments=cap_out,
    )
    idx = jnp.clip(jnp.sort(rep), 0, ct.cap - 1)
    return _gather_rows(
        ct, idx, n_out, unique=ct.unique | {frozenset(names)}
    )


def _c_attach_scalar(node: AttachScalar, ct: CTable, sub: CTable) -> CTable:
    q = node.sub.v
    while isinstance(q, Project):
        q = q.child
    if not (isinstance(q, Aggregate) and not q.keys):
        raise Unsupported("scalar subquery not provably single-row")
    m = sub.frame.meta(node.output)
    if _valid_name(node.output) in sub.frame.columns:
        raise Unsupported("nullable scalar subquery output")
    v = sub.frame.col_values(node.output)[0]
    f = ct.frame
    if m.kind == "float":
        out = f._append_float_column(
            node.name, jnp.full((ct.cap,), v, dtype=float_dtype())
        )
    else:
        out = f._append_int_column(
            node.name, jnp.full((ct.cap,), v, dtype=INT), m.kind, m.dictionary
        )
    return CTable(
        out, ct.n, ct.unique, ct.bounds, ct.mask, ct.fdeps, ct.dbound
    )


def _c_scan(node: Scan, ctx) -> CTable:
    if node.predicates:
        raise Unsupported("scan with pushed predicates")
    base = ctx.base_table(node.table)
    f = base.frame.select(list(node.columns))
    f = f.rename({c: f"{node.alias}.{c}" for c in node.columns})
    prep = ctx.preps[node.table]
    uniq = set()
    have = set(node.columns)
    for combo, verdict in prep.combos.items():
        if verdict and set(combo) <= have:
            uniq.add(frozenset(f"{node.alias}.{c}" for c in combo))
    bounds = {
        f"{node.alias}.{c}": prep.bounds[c]
        for c in node.columns
        if c in prep.bounds
    }
    return CTable(f, base.n, uniq, bounds)


def _c_lower(node, ctx, memo: Dict) -> CTable:
    if isinstance(node, Shared):
        if node not in memo:
            memo[node] = _c_lower(node.child, ctx, memo)
        return memo[node]
    if isinstance(node, Scan):
        return _c_scan(node, ctx)
    if isinstance(node, Filter):
        return _c_filter(node, _c_lower(node.child, ctx, memo), ctx)
    if isinstance(node, Join):
        return _c_join(
            node,
            _c_lower(node.left, ctx, memo),
            _c_lower(node.right, ctx, memo),
        )
    if isinstance(node, Aggregate):
        return _c_aggregate(node, _c_lower(node.child, ctx, memo), ctx)
    if isinstance(node, Project):
        return _c_project(node, _c_lower(node.child, ctx, memo), ctx)
    if isinstance(node, Sort):
        return _c_sort(node, _c_lower(node.child, ctx, memo))
    if isinstance(node, Limit):
        if isinstance(node.child, Sort) and int(node.n) <= 1 << 12:
            return _c_topk(
                node.child,
                int(node.n),
                _c_lower(node.child.child, ctx, memo),
            )
        return _c_limit(node, _c_lower(node.child, ctx, memo))
    if isinstance(node, Distinct):
        return _c_distinct(_c_lower(node.child, ctx, memo))
    if isinstance(node, AttachScalar):
        return _c_attach_scalar(
            node,
            _c_lower(node.child, ctx, memo),
            _c_lower(node.sub.v, ctx, memo),
        )
    raise Unsupported(f"plan node {type(node).__name__}")


def _finalize(ct: CTable) -> CTable:
    """Compact the result to fresh, dead-slot-free payload tensors so
    the program returns exactly what the caller slices."""
    ct = _compact(ct)
    f = ct.frame
    islots: List[int] = []
    fslots: List[int] = []
    cols: Dict[str, ColumnMeta] = {}
    for name, m in f.columns.items():
        if m.kind == "float":
            cols[name] = dataclasses.replace(m, slot=len(fslots), block=0)
            fslots.append(m.slot)
        else:
            cols[name] = dataclasses.replace(m, slot=len(islots), block=0)
            islots.append(m.slot)
    it = (
        f.itensor[:, jnp.asarray(islots, dtype=INT)]
        if islots
        else _empty_tensor(f.nrows, INT)
    )
    ft = (
        f.ftensor[:, jnp.asarray(fslots, dtype=INT)]
        if fslots
        else _empty_tensor(f.nrows, float_dtype())
    )
    out = TensorFrame(it, ft, cols, {}, f.nrows)
    return CTable(out, jnp.asarray(ct.n, dtype=INT), ct.unique)


# ----------------------------------------------------------------------
# trace context + compiled-program construction
# ----------------------------------------------------------------------
class _Ctx:
    def __init__(self, bases, preps, params_i, params_f, slots):
        self.bases = bases  # table -> (itensor, ftensor, n) traced
        self.preps = preps
        self.params_i = params_i
        self.params_f = params_f
        self.slots = slots  # global param index -> ('i'|'f', position)
        self._base_memo: Dict[str, CTable] = {}

    def base_table(self, name: str) -> CTable:
        got = self._base_memo.get(name)
        if got is None:
            it, ft, n = self.bases[name]
            prep = self.preps[name]
            cols = {
                k: dataclasses.replace(m)
                for k, m in prep.frame.columns.items()
            }
            got = CTable(TensorFrame(it, ft, cols, {}, prep.cap), n)
            self._base_memo[name] = got
        return got

    def bind(self, e):
        if not self.slots:
            return e

        def fn(n):
            if isinstance(n, SParam):
                tag, j = self.slots[n.index]
                arr = self.params_f if tag == "f" else self.params_i
                return _BoundParam(arr[j], n.kind)
            return n

        return transform(e, fn)


def _param_slots(kinds: List[str]):
    slots = []
    ni = nf = 0
    for k in kinds:
        if k == "float":
            slots.append(("f", nf))
            nf += 1
        else:
            slots.append(("i", ni))
            ni += 1
    return slots, ni, nf


class _Entry:
    __slots__ = (
        "compiled",
        "columns",
        "cap",
        "order",
        "digest",
        "trace_s",
        "compile_s",
    )

    def __init__(self, compiled, columns, cap, order, digest, trace_s, compile_s):
        self.compiled = compiled
        self.columns = columns
        self.cap = cap
        self.order = order
        self.digest = digest
        self.trace_s = trace_s
        self.compile_s = compile_s


def _donating() -> bool:
    # donation is a no-op on the CPU backend, so there the padded
    # inputs can be built once per base table and reused every call;
    # accelerators really consume donated buffers and need fresh ones
    return jax.default_backend() != "cpu"


def _build_args(preps, order, values, slots, n_i, n_f):
    args = []
    fresh = _donating()
    for name in order:
        prep = preps[name]
        f = prep.frame
        if fresh or prep.pads is None:
            pads = (
                _pad_rows(f.itensor, prep.cap),
                _pad_rows(f.ftensor, prep.cap),
                jnp.asarray(f.nrows, dtype=INT),
            )
            if not fresh:
                prep.pads = pads
        else:
            pads = prep.pads
        args.extend(pads)
    vi = np.zeros((n_i,), dtype=np.int64)
    vf = np.zeros((n_f,), dtype=np.float64)
    for (kind, v), (tag, j) in zip(values, slots):
        if tag == "f":
            vf[j] = float(v)
        else:
            vi[j] = int(v)
    args.append(jnp.asarray(vi, dtype=INT))
    args.append(jnp.asarray(vf, dtype=float_dtype()))
    return args


def _pad_rows(t, cap: int):
    # always a FRESH buffer (never the base tensor itself): the padded
    # inputs are donated to the executable, and donating a shared
    # buffer would invalidate the caller's base table
    n = t.shape[0]
    out = jnp.zeros((cap, t.shape[1]), dtype=t.dtype)
    return out.at[:n].set(t)


def _compile_entry(fpr, pplan, preps, order, kinds, args):
    from repro.resilience.faults import fault_point

    fault_point("compile")
    slots, _, _ = _param_slots(kinds)
    captured: Dict = {}

    def run(*flat):
        i = 0
        bases = {}
        for name in order:
            bases[name] = (flat[i], flat[i + 1], flat[i + 2])
            i += 3
        ctx = _Ctx(bases, preps, flat[i], flat[i + 1], slots)
        out = _finalize(_c_lower(pplan, ctx, {}))
        captured["columns"] = out.frame.columns
        captured["cap"] = out.cap
        return out.frame.itensor, out.frame.ftensor, out.n

    donate = (
        tuple(j for j in range(3 * len(order)) if j % 3 != 2)
        if _donating()
        else ()
    )
    fn = jax.jit(run, donate_argnums=donate)
    with warnings.catch_warnings():
        # CPU backends cannot honor every donation; that is fine
        warnings.simplefilter("ignore")
        t0 = time.perf_counter()
        with obs.span("sql.compile.trace", fingerprint=fpr[:80]):
            lowered = fn.lower(*args)
        t1 = time.perf_counter()
        with obs.span("sql.compile.compile"):
            compiled = lowered.compile()
        t2 = time.perf_counter()
    digest = hashlib.sha1(fpr.encode()).hexdigest()[:12]
    return _Entry(
        compiled, captured["columns"], captured["cap"], order, digest,
        t1 - t0, t2 - t1,
    )


def _maybe_compile(fpr, pplan, preps, tables, kinds, args):
    """Resolve a cache miss: compile ``fpr``, or reuse the entry a
    racing thread produced while we waited on the trace lock.  The
    caller holds the per-fingerprint lock.  Returns None on fallback."""
    with _LOCK:
        entry = _CACHE.get(fpr)
        if entry is not None:
            STATS["hits"] += 1
            _CACHE.move_to_end(fpr)
            return entry
        if fpr in _NEGATIVE:
            STATS["fallbacks"] += 1
            return None
        STATS["misses"] += 1
    try:
        entry = _compile_entry(fpr, pplan, preps, tables, kinds, args)
    except _FALLBACK_ERRORS as e:
        with _LOCK:
            _NEGATIVE[fpr] = f"{type(e).__name__}: {e}"
            _TRACE_LOCKS.pop(fpr, None)
            STATS["fallbacks"] += 1
        return None
    except Exception as e:
        # an *unexpected* trace/compile crash (backend bug, injected
        # fault) must not poison serving: negative-cache the
        # fingerprint so the plan permanently dispatches op-by-op, and
        # release the trace lock so waiters aren't stuck behind it
        with _LOCK:
            _NEGATIVE[fpr] = f"compile failure {type(e).__name__}: {e}"
            _TRACE_LOCKS.pop(fpr, None)
            STATS["compile_failures"] += 1
            STATS["fallbacks"] += 1
        return None
    with _LOCK:
        STATS["compiles"] += 1
        _CACHE[fpr] = entry
        _TRACE_LOCKS.pop(fpr, None)
        while len(_CACHE) > CACHE_CAPACITY:
            _CACHE.popitem(last=False)
            STATS["evictions"] += 1
        rec = STATS["plans"].setdefault(
            entry.digest,
            {
                "tables": tables,
                "trace_s": 0.0,
                "compile_s": 0.0,
                "exec_s": 0.0,
                "calls": 0,
            },
        )
        rec["trace_s"] += entry.trace_s
        rec["compile_s"] += entry.compile_s
    return entry


_FALLBACK_ERRORS = (
    Unsupported,
    SqlError,
    jax.errors.ConcretizationTypeError,
    jax.errors.TracerArrayConversionError,
    jax.errors.TracerBoolConversionError,
    jax.errors.TracerIntegerConversionError,
)


def maybe_execute_compiled(plan, frames) -> Optional[TensorFrame]:
    """Run ``plan`` through the compiled path, or return None to let
    the caller dispatch op-by-op."""
    mode = CONFIG.compiled
    if mode == "off":
        return None
    scans: List[Scan] = list(walk_scans(plan))
    if not scans:
        return None
    tables = sorted({s.table for s in scans})
    for s in scans:
        if s.predicates:
            with _LOCK:
                STATS["fallbacks"] += 1
            return None
    for t in tables:
        if not isinstance(frames.get(t), TensorFrame):
            with _LOCK:
                STATS["fallbacks"] += 1
            return None
    udfs = active_udfs()
    if udfs and plan_uses_udf(plan, frozenset(udfs)):
        # the fingerprint keys on plan structure; it cannot capture the
        # python closure behind a session UDF -> op-by-op dispatch
        with _LOCK:
            STATS["fallbacks"] += 1
        return None
    if mode != "force":
        total = sum(frames[t].nrows for t in tables)
        if total < CONFIG.compiled_min_rows:
            with _LOCK:
                STATS["skipped_small"] += 1
            return None

    preps = {t: _prep_table(frames[t]) for t in tables}
    reqs: Dict[str, set] = {}
    _collect_unique_requests(plan, reqs)
    for t, combos in reqs.items():
        if t in preps:
            for combo in combos:
                _ensure_unique(preps[t], combo)

    try:
        pplan, values = parameterize(plan)
    except Unsupported:
        with _LOCK:
            STATS["fallbacks"] += 1
        return None
    kinds = [k for k, _ in values]
    fpr = "|".join(
        [
            repr(pplan),
            f"fd={CONFIG.float_dtype}",
            *(_table_sig(t, preps[t]) for t in tables),
        ]
    )
    with _LOCK:
        if fpr in _NEGATIVE:
            STATS["fallbacks"] += 1
            return None
        entry = _CACHE.get(fpr)
        if entry is not None:
            STATS["hits"] += 1
            _CACHE.move_to_end(fpr)
            tlock = None
        else:
            # one lock per in-flight fingerprint: concurrent first
            # compiles of the same plan serialize, distinct plans don't
            tlock = _TRACE_LOCKS.setdefault(fpr, threading.Lock())

    slots, n_i, n_f = _param_slots(kinds)
    args = _build_args(preps, tables, values, slots, n_i, n_f)

    cache_hit = entry is not None
    if entry is None:
        with tlock:
            entry = _maybe_compile(fpr, pplan, preps, tables, kinds, args)
        if entry is None:
            return None
        # tracing consumed (donated) the padded inputs; rebuild them
        args = _build_args(preps, tables, values, slots, n_i, n_f)

    t0 = time.perf_counter()
    with warnings.catch_warnings():
        # CPU backends cannot honor every donation; that is fine
        warnings.simplefilter("ignore")
        with obs.span(
            "sql.compile.execute", digest=entry.digest, cache_hit=cache_hit
        ) as sp:
            it, ft, n_out = entry.compiled(*args)
            n = int(n_out)  # host sync: the program really ran
            sp.set(rows=n)
    t1 = time.perf_counter()
    with _LOCK:
        rec = STATS["plans"].get(entry.digest)
        if rec is not None:
            rec["exec_s"] += t1 - t0
            rec["calls"] += 1
    cols = {k: dataclasses.replace(m) for k, m in entry.columns.items()}
    return TensorFrame(it[:n], ft[:n], cols, {}, n)
