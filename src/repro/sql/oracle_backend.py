"""Row-at-a-time plan interpreter over ``repro.core.oracle``.

Runs the (by default unoptimized) logical plan on the independent
oracle engine: Python lists, per-row expression evaluation, None as
NULL.  Used by the differential tests as the third leg of the
SQL-vs-hand-written-vs-oracle comparison — it shares the parser/planner
with the TensorFrame path but none of the execution machinery, so a
lowering or optimizer bug shows up as a mismatch.
"""
from __future__ import annotations

import datetime
import math
import re
from typing import Dict, List

import numpy as np

from repro.core import oracle as orc

from .parser import (
    SqlError,
    SAnd,
    SBetween,
    SBin,
    SCase,
    SCmp,
    SCol,
    SDate,
    SExtract,
    SFunc,
    SIn,
    SInterval,
    SIsNull,
    SLike,
    SLit,
    SNot,
    SOr,
    format_expr,
)
from .plan import Aggregate, Filter, Join, Limit, Project, Scan, Sort

_EPOCH = datetime.date(1970, 1, 1)


def _like_rx(pattern: str) -> "re.Pattern":
    parts = []
    for ch in pattern:
        if ch == "%":
            parts.append(".*")
        elif ch == "_":
            parts.append(".")
        else:
            parts.append(re.escape(ch))
    return re.compile("".join(parts), flags=re.S)


def _truthy(v) -> bool:
    return bool(v) if v is not None else False


def eval_row(e, row: dict):
    """Evaluate a SQL expression on one row dict (None = NULL)."""
    if isinstance(e, SCol):
        return row[e.internal]
    if isinstance(e, SLit):
        return e.value
    if isinstance(e, (SDate, SInterval)):
        return e.days
    if isinstance(e, SBin):
        a, b = eval_row(e.a, row), eval_row(e.b, row)
        if a is None or b is None:
            return None
        if e.op == "+":
            return a + b
        if e.op == "-":
            return a - b
        if e.op == "*":
            return a * b
        return a / b
    if isinstance(e, SCmp):
        a, b = eval_row(e.a, row), eval_row(e.b, row)
        if a is None or b is None:
            return None
        return {
            "=": a == b, "<>": a != b, "<": a < b,
            "<=": a <= b, ">": a > b, ">=": a >= b,
        }[e.op]
    if isinstance(e, SAnd):
        return _truthy(eval_row(e.a, row)) and _truthy(eval_row(e.b, row))
    if isinstance(e, SOr):
        return _truthy(eval_row(e.a, row)) or _truthy(eval_row(e.b, row))
    if isinstance(e, SNot):
        return not _truthy(eval_row(e.a, row))
    if isinstance(e, SIn):
        v = eval_row(e.e, row)
        if v is None:
            return None
        hit = v in tuple(eval_row(x, row) for x in e.values)
        return (not hit) if e.negated else hit
    if isinstance(e, SBetween):
        v = eval_row(e.e, row)
        lo, hi = eval_row(e.lo, row), eval_row(e.hi, row)
        if v is None or lo is None or hi is None:
            return None
        hit = lo <= v <= hi
        return (not hit) if e.negated else hit
    if isinstance(e, SLike):
        v = eval_row(e.e, row)
        if v is None:
            return None
        hit = bool(_like_rx(e.pattern).fullmatch(str(v)))
        return (not hit) if e.negated else hit
    if isinstance(e, SIsNull):
        v = eval_row(e.e, row)
        null = v is None or (isinstance(v, float) and math.isnan(v))
        return (not null) if e.negated else null
    if isinstance(e, SCase):
        for cond, res in e.whens:
            if _truthy(eval_row(cond, row)):
                return eval_row(res, row)
        return eval_row(e.default, row)
    if isinstance(e, SExtract):
        v = eval_row(e.e, row)
        if v is None:
            return None
        day = _EPOCH + datetime.timedelta(days=int(v))
        return {"year": day.year, "month": day.month, "day": day.day}[e.field]
    if isinstance(e, SFunc):
        if e.is_aggregate:
            raise SqlError("aggregate evaluated outside Aggregate node")
        v = eval_row(e.args[0], row)
        if v is None:
            return None
        fns = {
            "abs": abs, "sqrt": math.sqrt, "floor": math.floor,
            "exp": math.exp, "log": math.log, "sin": math.sin, "cos": math.cos,
        }
        if e.name not in fns:
            raise SqlError(f"unsupported function {e.name.upper()}")
        return fns[e.name](v)
    raise SqlError(f"oracle backend cannot evaluate {format_expr(e)}")


def _rows(df: orc.ODF) -> List[dict]:
    names = list(df)
    return [
        {k: df[k][i] for k in names} for i in range(orc.nrows(df))
    ]


def execute_oracle(plan, tables: Dict[str, Dict[str, np.ndarray]]) -> orc.ODF:
    """Interpret a logical plan on raw numpy tables via the oracle."""
    if isinstance(plan, Scan):
        if plan.table not in tables:
            raise SqlError(f"table {plan.table!r} missing from scope")
        raw = tables[plan.table]
        df = orc.from_numpy({c: raw[c] for c in plan.columns})
        return {f"{plan.alias}.{c}": v for c, v in df.items()}
    if isinstance(plan, Filter):
        df = execute_oracle(plan.child, tables)
        mask = [_truthy(eval_row(plan.pred, r)) for r in _rows(df)]
        return orc.o_filter(df, mask)
    if isinstance(plan, Join):
        left = execute_oracle(plan.left, tables)
        right = execute_oracle(plan.right, tables)
        return orc.o_join(
            left, right, list(plan.left_keys), list(plan.right_keys),
            how=plan.how,
        )
    if isinstance(plan, Aggregate):
        df = execute_oracle(plan.child, tables)
        rows = _rows(df)
        work: orc.ODF = {}
        for name, e in plan.keys:
            work[name] = [eval_row(e, r) for r in rows]
        specs = []
        for name, fn, e in plan.aggs:
            if fn == "size":
                specs.append((name, "size", ""))
                continue
            work[name + ".__in"] = [eval_row(e, r) for r in rows]
            specs.append((name, fn, name + ".__in"))
        keys = [n for n, _ in plan.keys]
        if keys:
            return orc.o_groupby(work, keys, specs)
        out: orc.ODF = {}
        for name, fn, cn in specs:
            v = orc._agg_one(work[cn] if cn else [1] * len(rows), fn)
            if v is None and fn == "sum":
                v = 0.0  # engine (pandas) semantics for empty SUM
            out[name] = [v]
        return out
    if isinstance(plan, Project):
        df = execute_oracle(plan.child, tables)
        rows = _rows(df)
        return {name: [eval_row(e, r) for r in rows] for name, e in plan.outputs}
    if isinstance(plan, Sort):
        df = execute_oracle(plan.child, tables)
        return orc.o_sort(
            df, [n for n, _ in plan.keys], [a for _, a in plan.keys]
        )
    if isinstance(plan, Limit):
        df = execute_oracle(plan.child, tables)
        return orc.o_take(df, range(min(plan.n, orc.nrows(df))))
    raise TypeError(f"unknown plan node {type(plan).__name__}")
