"""Row-at-a-time plan interpreter over ``repro.core.oracle``.

Runs the (by default unoptimized) logical plan on the independent
oracle engine: Python lists, per-row expression evaluation, None as
NULL.  Used by the differential tests as the third leg of the
SQL-vs-hand-written-vs-oracle comparison — it shares the parser/planner
with the TensorFrame path but none of the execution machinery, so a
lowering or optimizer bug shows up as a mismatch.

Subqueries are interpreted directly, nested-loop style: a planned
subquery marker re-executes its subplan for every outer row with the
row's values bound to the ``SOuter`` references — deliberately the
dumbest correct semantics, entirely independent of the optimizer's
decorrelation rewrites it cross-checks.  Executions are memoized per
distinct binding of the referenced outer columns so TPC-H-sized inputs
stay tractable.
"""
from __future__ import annotations

import datetime
import math
import re
from typing import Dict, List, Optional

import numpy as np

from repro.core import oracle as orc

from .parser import (
    SqlError,
    SAnd,
    SBetween,
    SBin,
    SCase,
    SCmp,
    SCol,
    SDate,
    SExtract,
    SFunc,
    SIn,
    SInterval,
    SIsNull,
    SLike,
    SLit,
    SNot,
    SOr,
    conjoin,
    expr_columns,
    format_expr,
)
from .plan import (
    Aggregate,
    AttachScalar,
    Distinct,
    ExistsExpr,
    Filter,
    InSubExpr,
    Join,
    Limit,
    Project,
    SOuter,
    Scan,
    Sort,
    SubqueryExpr,
    plan_outer_refs,
)

_EPOCH = datetime.date(1970, 1, 1)


class _Ctx:
    """Interpreter context: the table scope, the outer-row binding for
    correlated subqueries, and caches shared across the whole query.

    ``in_sub`` marks execution inside a *correlated* subquery: there an
    empty SUM is NULL (standard SQL — an empty correlated group must
    fail its comparison, which is also what the decorrelated join
    rewrite produces).  The top level and uncorrelated subqueries keep
    the engine's pandas-style empty SUM = 0.0 so all three differential
    legs agree."""

    __slots__ = ("tables", "outer", "memo", "scans", "in_sub")

    def __init__(self, tables, outer=None, memo=None, scans=None, in_sub=False):
        self.tables = tables
        self.outer = outer or {}
        self.memo = memo if memo is not None else {}
        self.scans = scans if scans is not None else {}
        self.in_sub = in_sub

    def bound(self, row: dict, correlated: bool) -> "_Ctx":
        return _Ctx(
            self.tables,
            {**self.outer, **row},
            self.memo,
            self.scans,
            correlated,
        )


def _like_rx(pattern: str) -> "re.Pattern":
    parts = []
    for ch in pattern:
        if ch == "%":
            parts.append(".*")
        elif ch == "_":
            parts.append(".")
        else:
            parts.append(re.escape(ch))
    return re.compile("".join(parts), flags=re.S)


def _truthy(v) -> bool:
    return bool(v) if v is not None else False


def eval_row(e, row: dict, ctx: Optional[_Ctx] = None):
    """Evaluate a SQL expression on one row dict (None = NULL)."""
    if isinstance(e, SCol):
        return row[e.internal]
    if isinstance(e, SLit):
        return e.value
    if isinstance(e, (SDate, SInterval)):
        return e.days
    if isinstance(e, SOuter):
        if ctx is None or e.internal not in ctx.outer:
            raise SqlError(
                f"correlated reference {e.internal} has no outer binding"
            )
        return ctx.outer[e.internal]
    if isinstance(e, SubqueryExpr):
        sub = _run_subquery(e, row, ctx)
        n = orc.nrows(sub)
        if n == 0:
            return None
        if n > 1:
            raise SqlError(f"scalar subquery {e.name} returned {n} rows")
        return sub[e.output][0]
    if isinstance(e, InSubExpr):
        # join semantics, matching the semi/anti decorrelation (and the
        # engine's null-keys-never-match joins) rather than SQL's
        # three-valued NOT IN: NULLs on either side simply never match
        v = eval_row(e.e, row, ctx)
        if v is None:
            return e.negated
        hit = v in _run_subquery(e, row, ctx)[e.output]
        return hit != e.negated
    if isinstance(e, ExistsExpr):
        hit = orc.nrows(_run_subquery(e, row, ctx)) > 0
        return (not hit) if e.negated else hit
    if isinstance(e, SBin):
        a, b = eval_row(e.a, row, ctx), eval_row(e.b, row, ctx)
        if a is None or b is None:
            return None
        if e.op == "+":
            return a + b
        if e.op == "-":
            return a - b
        if e.op == "*":
            return a * b
        return a / b
    if isinstance(e, SCmp):
        a, b = eval_row(e.a, row, ctx), eval_row(e.b, row, ctx)
        if a is None or b is None:
            return None
        return {
            "=": a == b, "<>": a != b, "<": a < b,
            "<=": a <= b, ">": a > b, ">=": a >= b,
        }[e.op]
    if isinstance(e, SAnd):
        return _truthy(eval_row(e.a, row, ctx)) and _truthy(eval_row(e.b, row, ctx))
    if isinstance(e, SOr):
        return _truthy(eval_row(e.a, row, ctx)) or _truthy(eval_row(e.b, row, ctx))
    if isinstance(e, SNot):
        return not _truthy(eval_row(e.a, row, ctx))
    if isinstance(e, SIn):
        v = eval_row(e.e, row, ctx)
        if v is None:
            return None
        hit = v in tuple(eval_row(x, row, ctx) for x in e.values)
        return (not hit) if e.negated else hit
    if isinstance(e, SBetween):
        v = eval_row(e.e, row, ctx)
        lo, hi = eval_row(e.lo, row, ctx), eval_row(e.hi, row, ctx)
        if v is None or lo is None or hi is None:
            return None
        hit = lo <= v <= hi
        return (not hit) if e.negated else hit
    if isinstance(e, SLike):
        v = eval_row(e.e, row, ctx)
        if v is None:
            return None
        hit = bool(_like_rx(e.pattern).fullmatch(str(v)))
        return (not hit) if e.negated else hit
    if isinstance(e, SIsNull):
        v = eval_row(e.e, row, ctx)
        null = v is None or (isinstance(v, float) and math.isnan(v))
        return (not null) if e.negated else null
    if isinstance(e, SCase):
        for cond, res in e.whens:
            if _truthy(eval_row(cond, row, ctx)):
                return eval_row(res, row, ctx)
        return eval_row(e.default, row, ctx)
    if isinstance(e, SExtract):
        v = eval_row(e.e, row, ctx)
        if v is None:
            return None
        day = _EPOCH + datetime.timedelta(days=int(v))
        return {"year": day.year, "month": day.month, "day": day.day}[e.field]
    if isinstance(e, SFunc):
        if e.is_aggregate:
            raise SqlError("aggregate evaluated outside Aggregate node")
        if e.name == "substring":
            v = eval_row(e.args[0], row, ctx)
            if v is None:
                return None
            start = int(eval_row(e.args[1], row, ctx))
            length = int(eval_row(e.args[2], row, ctx))
            return str(v)[start - 1:start - 1 + length]
        v = eval_row(e.args[0], row, ctx)
        if v is None:
            return None
        fns = {
            "abs": abs, "sqrt": math.sqrt, "floor": math.floor,
            "exp": math.exp, "log": math.log, "sin": math.sin, "cos": math.cos,
        }
        if e.name not in fns:
            raise SqlError(f"unsupported function {e.name.upper()}")
        return fns[e.name](v)
    raise SqlError(f"oracle backend cannot evaluate {format_expr(e)}")


def _run_subquery(marker, row: dict, ctx: Optional[_Ctx]) -> orc.ODF:
    """Execute a planned subquery with the current row bound as the
    outer scope; memoized on the values of its outer references."""
    if ctx is None:
        raise SqlError("subquery evaluation needs an interpreter context")
    refs = ctx.memo.get(("refs", id(marker)))
    if refs is None:
        refs = plan_outer_refs(marker.plan.v)
        ctx.memo[("refs", id(marker))] = refs
    bound = ctx.bound(row, correlated=bool(refs))
    key = (id(marker),) + tuple(bound.outer.get(r) for r in refs)
    hit = ctx.memo.get(key)
    if hit is None:
        hit = _exec(marker.plan.v, bound)
        ctx.memo[key] = hit
    return hit


def _rows(df: orc.ODF) -> List[dict]:
    names = list(df)
    return [
        {k: df[k][i] for k in names} for i in range(orc.nrows(df))
    ]


def execute_oracle(plan, tables: Dict[str, Dict[str, np.ndarray]]) -> orc.ODF:
    """Interpret a logical plan on raw numpy tables via the oracle."""
    return _exec(plan, _Ctx(tables))


def _exec(plan, ctx: _Ctx) -> orc.ODF:
    if isinstance(plan, Scan):
        # correlated subqueries re-execute their subtree per outer
        # binding; the scan itself never depends on the binding, so
        # cache the converted table across executions
        cached = ctx.scans.get(id(plan))
        if cached is not None:
            return cached
        if plan.table not in ctx.tables:
            raise SqlError(f"table {plan.table!r} missing from scope")
        raw = ctx.tables[plan.table]
        df = orc.from_numpy({c: raw[c] for c in plan.columns})
        out = {f"{plan.alias}.{c}": v for c, v in df.items()}
        if plan.predicates:
            # predicates pushed into a (store-backed) scan left the
            # plan's Filters; interpreting them here keeps the oracle
            # usable on store-optimized plans too.  Pruning may have
            # narrowed the scan's output past the predicate columns, so
            # evaluate against a widened row view.
            need = {
                c.split(".", 1)[1]
                for p in plan.predicates
                for c in expr_columns(p)
            } - set(plan.columns)
            full = dict(out)
            if need:
                extra = orc.from_numpy({c: raw[c] for c in need})
                full.update(
                    {f"{plan.alias}.{c}": v for c, v in extra.items()}
                )
            pred = conjoin(list(plan.predicates))
            mask = [_truthy(eval_row(pred, r, ctx)) for r in _rows(full)]
            out = orc.o_filter(out, mask)
        ctx.scans[id(plan)] = out
        return out
    if isinstance(plan, Filter):
        df = _exec(plan.child, ctx)
        mask = [_truthy(eval_row(plan.pred, r, ctx)) for r in _rows(df)]
        return orc.o_filter(df, mask)
    if isinstance(plan, Join):
        left = _exec(plan.left, ctx)
        right = _exec(plan.right, ctx)
        return orc.o_join(
            left, right, list(plan.left_keys), list(plan.right_keys),
            how=plan.how,
        )
    if isinstance(plan, Aggregate):
        df = _exec(plan.child, ctx)
        rows = _rows(df)
        work: orc.ODF = {}
        for name, e in plan.keys:
            work[name] = [eval_row(e, r, ctx) for r in rows]
        specs = []
        for name, fn, e in plan.aggs:
            if fn == "size":
                specs.append((name, "size", ""))
                continue
            work[name + ".__in"] = [eval_row(e, r, ctx) for r in rows]
            specs.append((name, fn, name + ".__in"))
        keys = [n for n, _ in plan.keys]
        if keys:
            return orc.o_groupby(work, keys, specs)
        out: orc.ODF = {}
        for name, fn, cn in specs:
            v = orc._agg_one(work[cn] if cn else [1] * len(rows), fn)
            if v is None and fn == "sum" and not ctx.in_sub:
                v = 0.0  # engine (pandas) semantics for empty SUM
            out[name] = [v]
        return out
    if isinstance(plan, Project):
        df = _exec(plan.child, ctx)
        rows = _rows(df)
        return {
            name: [eval_row(e, r, ctx) for r in rows]
            for name, e in plan.outputs
        }
    if isinstance(plan, Sort):
        df = _exec(plan.child, ctx)
        return orc.o_sort(
            df, [n for n, _ in plan.keys], [a for _, a in plan.keys]
        )
    if isinstance(plan, Limit):
        df = _exec(plan.child, ctx)
        return orc.o_take(df, range(min(plan.n, orc.nrows(df))))
    if isinstance(plan, Distinct):
        df = _exec(plan.child, ctx)
        names = list(df)
        seen, keep = set(), []
        for i in range(orc.nrows(df)):
            key = tuple(df[k][i] for k in names)
            if key not in seen:
                seen.add(key)
                keep.append(i)
        return orc.o_take(df, keep)
    if isinstance(plan, AttachScalar):
        df = _exec(plan.child, ctx)
        sub = _exec(plan.sub.v, ctx)
        if orc.nrows(sub) > 1:
            raise SqlError(
                f"scalar subquery {plan.name} returned {orc.nrows(sub)} rows"
            )
        v = sub[plan.output][0] if orc.nrows(sub) == 1 else None  # 0 rows = NULL
        return {**df, plan.name: [v] * orc.nrows(df)}
    raise TypeError(f"unknown plan node {type(plan).__name__}")
