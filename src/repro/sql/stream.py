"""Chunk-streaming lowering for Aggregate-over-store-scan plans.

``try_stream_aggregate`` recognizes the morsel-friendly plan shape

    Aggregate
      └─ {Filter | Join(probe=left, build=right)}*
           └─ Scan(store-backed table)

and executes it through ``repro.core.pipeline`` instead of the eager
lowering: the probe scan streams chunk by chunk (prefetching decode
while the device computes), each join's build side is lowered eagerly
ONCE and probed per chunk (``HashBuild``), and the aggregate folds into
spill-managed partials merged every ``CONFIG.ooc_merge_every`` chunks
(``StreamAgg``).  Peak memory is bounded by chunk size + build sides +
the partial pool budget, not by the scan's row count.

Gating (``CONFIG.out_of_core``): ``off`` never streams; ``auto``
streams when the probe table has at least ``CONFIG.ooc_min_rows`` rows;
``force`` streams whenever the plan shape allows — the mode the
memory-capped CI lane runs.  Returns ``None`` to fall back to the eager
lowering when the shape doesn't match: unsupported aggregate functions,
probe columns carrying null bitmaps (partial re-aggregation would need
null-preserving key transport), aggregate outputs shadowing group keys,
or a non-store probe source.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core import TensorFrame
from repro.core.config import CONFIG
from repro.store import Table as StoreTable

from .plan import Aggregate, Filter, Join, Scan


def try_stream_aggregate(
    node: Aggregate, frames: Dict, _memo=None
) -> Optional[TensorFrame]:
    mode = CONFIG.out_of_core
    if mode == "off":
        return None
    # unsupported aggregate functions / key-shadowing outputs
    from repro.core import pipeline

    for _, fn, _ in node.aggs:
        if fn not in pipeline.STREAMABLE_AGGS:
            return None
    key_names = [name for name, _ in node.keys]
    if set(key_names) & {name for name, _, _ in node.aggs}:
        return None

    # walk the probe chain: Filter/Join links down to a store Scan
    chain: List = []
    cur = node.child
    while True:
        if isinstance(cur, Filter):
            chain.append(cur)
            cur = cur.child
        elif isinstance(cur, Join) and cur.how in (
            "inner",
            "left",
            "semi",
            "anti",
        ):
            chain.append(cur)
            cur = cur.left
        elif isinstance(cur, Scan):
            break
        else:
            return None
    src = frames.get(cur.table)
    if not isinstance(src, StoreTable):
        return None
    if mode == "auto" and src.nrows < CONFIG.ooc_min_rows:
        return None
    # conservative null gate: partial blocks round-trip through host
    # dicts, which cannot carry key/value nulls faithfully yet
    for c in cur.columns:
        if src.columns[c].has_validity():
            return None

    from .lower import _scan_pred, lower_plan, prepare_aggregate_inputs, to_expr

    try:
        preds = [_scan_pred(c, cur.alias) for c in cur.predicates]
    except Exception:
        return None

    # build sides lower eagerly, ONCE, before any chunk streams
    ops: List = []  # bottom-up ("filter", expr) | ("join", HashBuild)
    for link in reversed(chain):
        if isinstance(link, Filter):
            ops.append(("filter", to_expr(link.pred)))
        else:
            build = lower_plan(link.right, frames, _memo)
            ops.append(
                (
                    "join",
                    pipeline.HashBuild(
                        list(link.left_keys),
                        build,
                        list(link.right_keys),
                        link.how,
                    ),
                )
            )

    from repro import obs

    ren = {c: f"{cur.alias}.{c}" for c in cur.columns}
    cs = pipeline.ChunkScan(src, list(cur.columns), preds)
    sagg: Optional[pipeline.StreamAgg] = None

    def _make_rebuild(idx: int):
        """Recompute closure for one chunk's aggregate contribution:
        re-scan the chunk from the durable store and replay the op
        chain (``disjoint`` is only a fast path, so a plain replay is
        result-identical).  Carried by the chunk's spilled partial so
        a corrupt spill block repairs itself (``spill.corrupt_blocks``
        / ``spill.recomputes``)."""

        def rebuild() -> TensorFrame:
            from repro import store as _store

            res = _store.scan_chunk(src, cs.proj, cs.phys_preds, int(idx))
            f = TensorFrame.from_store(
                src, cs.proj, [], result=res
            ).rename(ren)
            for kind, op in ops:
                f = f.filter(op) if kind == "filter" else op.apply(f)
            return prepare_aggregate_inputs(node, f)[0]

        return rebuild

    with obs.span(
        "pipeline.stream_agg", table=cur.table, chunks=len(cs)
    ):
        for chunk_idx, f in cs.iter_indexed():
            f = f.rename(ren)
            for kind, op in ops:
                if kind == "filter":
                    f = f.filter(op)
                else:
                    hb = op
                    if hb.disjoint(f):
                        # zone-map bounds prove no key matches this chunk
                        if hb.how == "anti":
                            continue  # every row survives, unprobed
                        if hb.how in ("inner", "semi"):
                            pipeline.STATS["chunks_pruned"] += 1
                            f = None
                            break
                    f = hb.apply(f)
                if f.nrows == 0:
                    f = None
                    break
            if f is None:
                continue
            f, keys, specs = prepare_aggregate_inputs(node, f)
            if sagg is None:
                sagg = pipeline.StreamAgg(keys, specs)
            sagg.add(f, rebuild=_make_rebuild(chunk_idx))
        pipeline.STATS["pipelines"] += 1
        pipeline.sync_spill_stats()
        if sagg is None:
            pipeline.STATS["fallbacks"] += 1
            return None  # nothing streamed (empty scan): eager path cheap
        out = sagg.finalize()
        if out is None:
            pipeline.STATS["fallbacks"] += 1
        return out
