"""Per-session scalar UDFs, lowered through ``jax.vmap``.

The serving layer (``repro.serve.sql``) registers python scalar
functions per executor/session (framequery's ``add_function`` surface).
A registered function sees one *scalar* per argument; the engine lowers
a call over whole columns by ``jax.vmap``-ing it once and applying the
vectorized function to the evaluated argument arrays — so a UDF written
as ``lambda price, disc: price * (1 - disc)`` runs as one fused device
expression, not a python loop.

Registration is scoped, not global: ``udf_scope(mapping)`` installs an
active registry for the duration of a query (a ``contextvars`` context
var, so concurrent sessions on different threads never see each other's
functions), and ``sql.lower.to_expr`` consults ``active_udfs()`` when
it meets a function name it doesn't know.  The compiled whole-plan path
declines plans that call an active UDF (``plan_uses_udf``) — the plan
cache keys on plan *structure* and must not capture a python closure —
so UDF queries run through op-by-op dispatch, where the vmapped kernel
is still a single fused call.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Callable, Mapping, Optional

from repro.core.expr import Expr, Value, _combine_valid
from repro.core.frame import INT, float_dtype

from .parser import SFunc, SqlError, transform

__all__ = [
    "Udf",
    "active_udfs",
    "plan_uses_udf",
    "udf_scope",
]

_KINDS = ("num", "bool")


class Udf:
    """A named scalar function: python scalars in, scalar out.

    ``returns`` declares the SQL-side kind of the result: ``"num"``
    (default) or ``"bool"`` (usable in WHERE).  The vmapped callable is
    built lazily on first use and cached, so registration itself never
    touches jax.
    """

    __slots__ = ("name", "fn", "returns", "_vfn", "calls")

    def __init__(self, name: str, fn: Callable, returns: str = "num"):
        if returns not in _KINDS:
            raise ValueError(
                f"UDF {name!r}: returns must be one of {_KINDS}, "
                f"not {returns!r}"
            )
        self.name = name.lower()
        self.fn = fn
        self.returns = returns
        self._vfn = None
        self.calls = 0  # column-level evaluations (not rows)

    def vectorized(self) -> Callable:
        if self._vfn is None:
            import jax

            self._vfn = jax.vmap(self.fn)
        return self._vfn


_ACTIVE: contextvars.ContextVar[Optional[Mapping[str, Udf]]] = (
    contextvars.ContextVar("repro_sql_udfs", default=None)
)


def active_udfs() -> Mapping[str, Udf]:
    """The UDF registry installed for the current context (or {})."""
    return _ACTIVE.get() or {}


@contextlib.contextmanager
def udf_scope(udfs: Mapping[str, Udf]):
    """Install ``udfs`` as the active registry for the enclosed query
    execution.  Context-local: safe under concurrent sessions."""
    token = _ACTIVE.set(dict(udfs))
    try:
        yield
    finally:
        _ACTIVE.reset(token)


@dataclasses.dataclass(eq=False)
class UdfCall(Expr):
    """Core expression applying a vmapped UDF to evaluated columns."""

    udf: Udf
    args: tuple

    def eval(self, frame) -> Value:
        vals = [a.eval(frame) for a in self.args]
        arrs = []
        for v in vals:
            if v.kind == "str":
                raise SqlError(
                    f"UDF {self.udf.name!r} cannot take string arguments"
                )
            arrs.append(v.arr)
        self.udf.calls += 1
        out = self.udf.vectorized()(*arrs)
        if self.udf.returns == "bool":
            out = out.astype(bool)
            return Value("bool", out, valid=_combine_valid(*[v.valid for v in vals]))
        if out.dtype.kind in ("i", "u", "b"):
            out = out.astype(INT)
        else:
            out = out.astype(float_dtype())
        return Value("num", out, valid=_combine_valid(*[v.valid for v in vals]))


def plan_uses_udf(plan, names) -> bool:
    """True when any expression in ``plan`` calls a function whose
    (lowercase) name is in ``names``.  Walks Boxed subplans too."""
    if not names:
        return False
    from .plan import AttachScalar, iter_plan_exprs

    hit = False

    def probe(e):
        nonlocal hit
        if isinstance(e, SFunc) and e.name in names:
            hit = True
        return e

    def roots(node):
        # iter_plan_exprs covers one plan tree but never crosses into
        # the Boxed subquery plans AttachScalar carries; surface those
        # as additional roots
        yield node
        stack = [node]
        while stack:
            n = stack.pop()
            if isinstance(n, AttachScalar):
                yield n.sub.v
                stack.append(n.sub.v)
            for attr in ("child", "left", "right"):
                c = getattr(n, attr, None)
                if c is not None:
                    stack.append(c)

    for root in roots(plan):
        for e in iter_plan_exprs(root):
            transform(e, probe)
            if hit:
                return True
    return hit
