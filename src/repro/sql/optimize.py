"""Rule-based plan optimizer.

Three rewrites, applied in order:

1. **Constant folding** — literal arithmetic/comparisons and
   DATE +/- INTERVAL collapse at plan time, so e.g. TPC-H Q1's
   ``DATE '1998-12-01' - INTERVAL '90' DAY`` becomes one date literal
   and Q6's ``0.06 - 0.01`` bounds become plain numbers.
2. **Filter pushdown** — the planner leaves one big Filter above the
   join tree; this rule splits it into conjuncts and pushes each as far
   down as its columns allow: through inner joins to either side,
   through left joins to the left (probe) side only, and through
   aggregates when a conjunct touches only plain group-key columns.
   Single-table predicates end up directly above their Scan, shrinking
   every join build/probe input (Flare's plan-level pushdown).
3. **Projection pruning** — a top-down required-column pass narrows
   every Scan to the columns the query actually touches, so joins
   materialize fewer columns and offloaded strings stay offloaded.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Set

from .parser import (
    SAnd,
    SBin,
    SCase,
    SCmp,
    SCol,
    SDate,
    SInterval,
    SLit,
    SNot,
    SOr,
    conjoin,
    expr_columns,
    split_conjuncts,
    transform,
)
from .plan import (
    Aggregate,
    Filter,
    Join,
    Limit,
    Project,
    Scan,
    Sort,
    node_columns,
)


def optimize(plan):
    """fold constants -> push filters -> prune projections."""
    plan = fold_constants(plan)
    plan = push_filters(plan)
    plan = prune_projections(plan)
    return plan


# ----------------------------------------------------------------------
# rule 1: constant folding
# ----------------------------------------------------------------------
_ARITH = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
}
_CMP = {
    "=": lambda a, b: a == b,
    "<>": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


def _is_num_lit(e) -> bool:
    return isinstance(e, SLit) and isinstance(e.value, (int, float)) and not isinstance(e.value, bool)


def _days(e) -> Optional[int]:
    if isinstance(e, (SDate, SInterval)):
        return e.days
    if isinstance(e, SLit) and isinstance(e.value, int) and not isinstance(e.value, bool):
        return e.value
    return None


def fold_expr_node(n):
    """One-step fold of a node whose children are already folded."""
    if isinstance(n, SBin):
        a, b = n.a, n.b
        if _is_num_lit(a) and _is_num_lit(b):
            if n.op == "/" and b.value == 0:
                return n
            return SLit(_ARITH[n.op](a.value, b.value))
        if isinstance(a, SDate) and n.op in ("+", "-"):
            nb = _days(b)
            if nb is not None and not isinstance(b, SDate):
                return SDate(a.days + nb if n.op == "+" else a.days - nb)
            if isinstance(b, SDate) and n.op == "-":
                return SLit(a.days - b.days)
        if isinstance(b, SDate) and n.op == "+":
            na = _days(a)
            if na is not None and not isinstance(a, SDate):
                return SDate(b.days + na)
        if isinstance(a, SInterval) and isinstance(b, SInterval):
            return SInterval(a.days + b.days if n.op == "+" else a.days - b.days)
    elif isinstance(n, SCmp):
        a, b = n.a, n.b
        if _is_num_lit(a) and _is_num_lit(b):
            return SLit(bool(_CMP[n.op](a.value, b.value)))
        if isinstance(a, SDate) and isinstance(b, SDate):
            return SLit(bool(_CMP[n.op](a.days, b.days)))
        if (
            isinstance(a, SLit) and isinstance(b, SLit)
            and isinstance(a.value, str) and isinstance(b.value, str)
        ):
            return SLit(bool(_CMP[n.op](a.value, b.value)))
    elif isinstance(n, SAnd):
        if n.a == SLit(True):
            return n.b
        if n.b == SLit(True):
            return n.a
        if SLit(False) in (n.a, n.b):
            return SLit(False)
    elif isinstance(n, SOr):
        if n.a == SLit(False):
            return n.b
        if n.b == SLit(False):
            return n.a
        if SLit(True) in (n.a, n.b):
            return SLit(True)
    elif isinstance(n, SNot):
        if isinstance(n.a, SLit) and isinstance(n.a.value, bool):
            return SLit(not n.a.value)
    elif isinstance(n, SCase):
        # drop WHEN branches with constant-false conditions
        whens = tuple((c, r) for c, r in n.whens if c != SLit(False))
        if whens != n.whens:
            if not whens:
                return n.default
            return SCase(whens, n.default)
    return n


def fold_expr(e):
    return transform(e, fold_expr_node)


def fold_constants(node):
    """Fold every expression embedded in the plan."""
    if isinstance(node, Filter):
        return Filter(fold_constants(node.child), fold_expr(node.pred))
    if isinstance(node, Project):
        return Project(
            fold_constants(node.child),
            tuple((n, fold_expr(e)) for n, e in node.outputs),
        )
    if isinstance(node, Aggregate):
        return Aggregate(
            fold_constants(node.child),
            tuple((n, fold_expr(e)) for n, e in node.keys),
            tuple(
                (n, fn, fold_expr(e) if e is not None else None)
                for n, fn, e in node.aggs
            ),
        )
    if isinstance(node, Join):
        return dataclasses.replace(
            node, left=fold_constants(node.left), right=fold_constants(node.right)
        )
    if isinstance(node, (Sort, Limit)):
        return dataclasses.replace(node, child=fold_constants(node.child))
    return node


# ----------------------------------------------------------------------
# rule 2: filter pushdown
# ----------------------------------------------------------------------
def push_filters(node):
    if isinstance(node, Filter):
        conjuncts = split_conjuncts(node.pred)
        child = node.child
        # merge stacked filters before pushing
        while isinstance(child, Filter):
            conjuncts += split_conjuncts(child.pred)
            child = child.child
        conjuncts = [c for c in conjuncts if c != SLit(True)]
        if not conjuncts:
            return push_filters(child)
        return _push_into(child, conjuncts)
    if isinstance(node, Join):
        return dataclasses.replace(
            node, left=push_filters(node.left), right=push_filters(node.right)
        )
    if isinstance(node, (Project, Aggregate, Sort, Limit)):
        return dataclasses.replace(node, child=push_filters(node.child))
    return node


def _push_into(child, conjuncts):
    """Push a list of conjuncts into ``child``; returns the new subtree
    (residual conjuncts wrap it in a Filter)."""
    if isinstance(child, Join):
        lcols, rcols = node_columns(child.left), node_columns(child.right)
        to_left, to_right, stay = [], [], []
        for c in conjuncts:
            cols = expr_columns(c)
            if cols <= lcols:
                to_left.append(c)
            elif cols <= rcols and child.how == "inner":
                to_right.append(c)
            else:
                stay.append(c)
        left = Filter(child.left, conjoin(to_left)) if to_left else child.left
        right = Filter(child.right, conjoin(to_right)) if to_right else child.right
        out = Join(
            push_filters(left),
            push_filters(right),
            child.left_keys,
            child.right_keys,
            child.how,
        )
        return Filter(out, conjoin(stay)) if stay else out
    if isinstance(child, Aggregate):
        # a conjunct over plain-column group keys commutes with grouping
        plain_keys = {
            n for n, e in child.keys if isinstance(e, SCol) and e.internal == n
        }
        below, stay = [], []
        for c in conjuncts:
            (below if expr_columns(c) <= plain_keys else stay).append(c)
        out = child
        if below:
            out = dataclasses.replace(
                child, child=Filter(child.child, conjoin(below))
            )
        out = dataclasses.replace(out, child=push_filters(out.child))
        return Filter(out, conjoin(stay)) if stay else out
    child = push_filters(child)
    return Filter(child, conjoin(conjuncts))


# ----------------------------------------------------------------------
# rule 3: projection pruning
# ----------------------------------------------------------------------
def prune_projections(node, required: Optional[Set[str]] = None):
    """Narrow Scans to the columns actually referenced above them.

    ``required=None`` means "everything" (the root, and below nodes that
    need their child intact)."""
    if isinstance(node, Project):
        need = set()
        for _, e in node.outputs:
            need |= expr_columns(e)
        return Project(prune_projections(node.child, need), node.outputs)
    if isinstance(node, (Sort, Limit)):
        return dataclasses.replace(
            node, child=prune_projections(node.child, required)
        )
    if isinstance(node, Filter):
        need = None if required is None else required | expr_columns(node.pred)
        return Filter(prune_projections(node.child, need), node.pred)
    if isinstance(node, Aggregate):
        need = set()
        for _, e in node.keys:
            need |= expr_columns(e)
        for _, _, e in node.aggs:
            if e is not None:
                need |= expr_columns(e)
        return dataclasses.replace(
            node, child=prune_projections(node.child, need)
        )
    if isinstance(node, Join):
        need = (
            None
            if required is None
            else required | set(node.left_keys) | set(node.right_keys)
        )
        lcols, rcols = node_columns(node.left), node_columns(node.right)
        lneed = None if need is None else need & lcols
        rneed = None if need is None else need & rcols
        return Join(
            prune_projections(node.left, lneed),
            prune_projections(node.right, rneed),
            node.left_keys,
            node.right_keys,
            node.how,
        )
    if isinstance(node, Scan):
        if required is None:
            return node
        keep = tuple(
            c for c in node.columns if f"{node.alias}.{c}" in required
        )
        return dataclasses.replace(node, columns=keep)
    raise TypeError(f"unknown plan node {type(node).__name__}")
