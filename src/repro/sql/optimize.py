"""Rule-based plan optimizer.

Four rewrites, applied in order:

1. **Decorrelation** — planned subquery markers (the naive plan keeps
   them for the row-at-a-time oracle) are rewritten to relational
   operators: uncorrelated scalar subqueries become an attached
   constant (cross join with a one-row result), ``IN``/``NOT IN``
   become semi/anti joins on the subquery output, correlated
   ``EXISTS``/``NOT EXISTS`` become semi/anti joins on their equality
   correlation keys, and correlated scalar aggregates are re-keyed by
   the correlation columns into a group-by joined back to the outer
   query (HiFrames-style nested-query lowering).  A single ``<>``
   correlation residual under EXISTS is handled through a
   nunique/min aggregate (TPC-H Q21's shape).
2. **Constant folding** — literal arithmetic/comparisons and
   DATE +/- INTERVAL collapse at plan time, so e.g. TPC-H Q1's
   ``DATE '1998-12-01' - INTERVAL '90' DAY`` becomes one date literal
   and Q6's ``0.06 - 0.01`` bounds become plain numbers.
3. **Filter pushdown** — the planner leaves one big Filter above the
   join tree; this rule splits it into conjuncts and pushes each as far
   down as its columns allow: through inner joins to either side,
   through left joins to the left (probe) side only, and through
   aggregates when a conjunct touches only plain group-key columns.
   Single-table predicates end up directly above their Scan, shrinking
   every join build/probe input (Flare's plan-level pushdown).
4. **Projection pruning** — a top-down required-column pass narrows
   every Scan to the columns the query actually touches, so joins
   materialize fewer columns and offloaded strings stay offloaded.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Set

from .parser import (
    Boxed,
    SqlError,
    SAnd,
    SBetween,
    SBin,
    SCase,
    SCmp,
    SCol,
    SDate,
    SIn,
    SInterval,
    SIsNull,
    SLike,
    SLit,
    SNot,
    SOr,
    conjoin,
    expr_columns,
    like_prefix,
    split_conjuncts,
    transform,
    walk,
)
from .plan import (
    Aggregate,
    AttachScalar,
    Distinct,
    ExistsExpr,
    Filter,
    InSubExpr,
    Join,
    Limit,
    Project,
    Scan,
    Shared,
    Sort,
    SOuter,
    SubqueryExpr,
    _replace_subexpr,
    node_columns,
    subquery_markers,
)


def optimize(plan, store_tables=frozenset()):
    """decorrelate -> fold constants -> push filters (incl. through
    Projects) -> push sargable conjuncts into store scans -> prune.

    ``store_tables`` names the scope tables backed by ``repro.store``
    chunked tables: only their Scans accept pushed predicates (zone-map
    chunk skipping happens in the scan, so the conjunct leaves the plan
    entirely).  In-memory scans keep explicit Filters so plans over
    plain frames are unchanged.
    """
    plan = decorrelate(plan)
    plan = fold_constants(plan)
    plan = push_filters(plan)
    if store_tables:
        plan = push_scan_predicates(plan, frozenset(store_tables))
    plan = prune_projections(plan)
    return plan


# ----------------------------------------------------------------------
# rule 0: decorrelation (subquery markers -> joins / attached scalars)
# ----------------------------------------------------------------------
def decorrelate(plan):
    """Rewrite every planned subquery marker into join form.

    The result contains no markers and no ``SOuter`` references, so it
    can be lowered onto TensorFrame; shapes outside the supported
    rewrites raise ``SqlError`` instead of silently interpreting."""
    node = plan
    if isinstance(node, Filter):
        child = decorrelate(node.child)
        remaining: List[object] = []
        for c in split_conjuncts(node.pred):
            child, res = _rewrite_conjunct(child, c)
            if res is not None:
                remaining.append(res)
        return Filter(child, conjoin(remaining)) if remaining else child
    if isinstance(node, Join):
        return dataclasses.replace(
            node, left=decorrelate(node.left), right=decorrelate(node.right)
        )
    if isinstance(node, Project):
        child = decorrelate(node.child)
        outputs = []
        for n, e in node.outputs:
            for m in subquery_markers(e):
                if not isinstance(m, SubqueryExpr):
                    raise SqlError(
                        "EXISTS/IN subqueries are not supported in the "
                        "SELECT list"
                    )
                child, repl = _rewrite_select_scalar(child, m)
                e = _replace_subexpr(e, m, repl)
            outputs.append((n, e))
        return Project(child, tuple(outputs))
    if isinstance(node, Aggregate):
        for e in [e for _, e in node.keys] + [
            e for _, _, e in node.aggs if e is not None
        ]:
            if subquery_markers(e):
                raise SqlError(
                    "subqueries inside GROUP BY keys or aggregate "
                    "arguments are not supported"
                )
        return dataclasses.replace(node, child=decorrelate(node.child))
    if isinstance(node, (Sort, Limit, Distinct, Shared)):
        return dataclasses.replace(node, child=decorrelate(node.child))
    if isinstance(node, AttachScalar):
        return dataclasses.replace(
            node,
            child=decorrelate(node.child),
            sub=Boxed(decorrelate(node.sub.v)),
        )
    return node


def _rewrite_select_scalar(child, m: SubqueryExpr):
    """Scalar subquery used as a SELECT-list value.

    Only the uncorrelated form is supported: it attaches the constant.
    A correlated one would need outer-join (keep-row-with-NULL)
    semantics that the inner-join rewrite cannot provide."""
    from .plan import plan_outer_refs

    if plan_outer_refs(m.plan.v):
        raise SqlError(
            "correlated scalar subqueries are only supported in "
            "WHERE/HAVING, not in the SELECT list"
        )
    return _rewrite_scalar(child, m)


def _rewrite_conjunct(child, c):
    """Rewrite one Filter conjunct; returns (new child, residual
    predicate or None)."""
    if isinstance(c, ExistsExpr):
        return _rewrite_exists(child, c)
    if isinstance(c, InSubExpr):
        return _rewrite_in(child, c)
    markers = subquery_markers(c)
    if not markers:
        return child, c
    for m in markers:
        if not isinstance(m, SubqueryExpr):
            raise SqlError(
                f"{type(m).__name__.replace('Expr', '').upper()} subqueries "
                f"are only supported as top-level AND conjuncts of "
                f"WHERE/HAVING, not nested inside other expressions"
            )
        child, repl = _rewrite_scalar(child, m)
        c = _replace_subexpr(c, m, repl)
    return child, c


def _strip_wrappers(p, what, drop_project=False, drop_distinct=False):
    """Peel semantics-free wrappers off a subquery plan.

    Sort never affects a subquery's value; Distinct is dropped only
    where duplicates cannot matter (EXISTS / IN membership).  LIMIT
    *does* change the result: an uncorrelated ``Limit(Sort(...))``
    subtree is kept intact and executed directly (deterministic thanks
    to the engine's stable tiebreak sort — LIMIT under sort ties picks
    the same rows as any stable reference); nothing below it may be
    stripped.  A correlated LIMIT has no join rewrite and is rejected.
    """
    while True:
        if isinstance(p, Sort):
            p = p.child
        elif isinstance(p, Distinct) and drop_distinct:
            p = p.child
        elif isinstance(p, Limit):
            from .plan import plan_outer_refs

            if plan_outer_refs(p):
                raise SqlError(
                    f"LIMIT inside correlated {what} subqueries is not "
                    f"supported (no join rewrite preserves the cutoff)"
                )
            return p
        elif isinstance(p, Distinct):
            raise SqlError(
                f"SELECT DISTINCT inside {what} subqueries is not supported"
            )
        else:
            break
    if drop_project and isinstance(p, Project):
        p = p.child
    return p


def _strip_correlation(node, under_agg=False):
    """Remove correlation conjuncts from a subquery plan.

    Returns ``(plan, eqs, neqs)`` with eqs/neqs lists of
    ``(outer_internal, inner_internal, under_aggregate)`` taken from
    ``inner = outer`` / ``inner <> outer`` Filter conjuncts.  Any other
    predicate that still references an enclosing scope is unsupported.
    """
    if isinstance(node, Filter):
        child, eqs, neqs = _strip_correlation(node.child, under_agg)
        keep = []
        for c in split_conjuncts(node.pred):
            kind, pair = _classify_correlation(c, under_agg)
            if kind == "eq":
                eqs.append(pair)
            elif kind == "neq":
                neqs.append(pair)
            else:
                keep.append(c)
        out = Filter(child, conjoin(keep)) if keep else child
        return out, eqs, neqs
    if isinstance(node, Join):
        left, e1, n1 = _strip_correlation(node.left, under_agg)
        right, e2, n2 = _strip_correlation(node.right, under_agg)
        return (
            dataclasses.replace(node, left=left, right=right),
            e1 + e2,
            n1 + n2,
        )
    if isinstance(node, Aggregate):
        child, eqs, neqs = _strip_correlation(node.child, True)
        return dataclasses.replace(node, child=child), eqs, neqs
    if isinstance(node, (Project, Sort, Limit, Distinct)):
        child, eqs, neqs = _strip_correlation(node.child, under_agg)
        return dataclasses.replace(node, child=child), eqs, neqs
    if isinstance(node, AttachScalar):
        child, eqs, neqs = _strip_correlation(node.child, under_agg)
        return dataclasses.replace(node, child=child), eqs, neqs
    return node, [], []


def _classify_correlation(c, under_agg):
    """One conjunct -> ('eq'|'neq', (outer, inner, under_agg)) or
    (None, None) for a plain local predicate."""
    if isinstance(c, SCmp) and c.op in ("=", "<>"):
        a, b = c.a, c.b
        if isinstance(a, SOuter) and not _has_outer(b):
            outer, inner = a, b
        elif isinstance(b, SOuter) and not _has_outer(a):
            outer, inner = b, a
        else:
            outer = None
        if outer is not None:
            if not isinstance(inner, SCol):
                raise SqlError(
                    f"correlated predicate must compare an outer column "
                    f"to a plain subquery column, got a computed "
                    f"expression on the inner side"
                )
            kind = "eq" if c.op == "=" else "neq"
            return kind, (outer.internal, inner.internal, under_agg)
    if _has_outer(c):
        raise SqlError(
            "unsupported correlated predicate shape (only "
            "inner = outer and inner <> outer conjuncts decorrelate)"
        )
    return None, None


def _has_outer(e) -> bool:
    return any(isinstance(n, SOuter) for n in walk(e))


def _check_outer_available(child, refs, what):
    cols = node_columns(child)
    for o in refs:
        if o not in cols:
            raise SqlError(
                f"correlated reference {o!r} in {what} is not available "
                f"in the immediately enclosing query (multi-level "
                f"correlation is not supported)"
            )


def _dedupe_pairs(pairs):
    seen, out = set(), []
    for o, i, _ in pairs:
        if (o, i) not in seen:
            seen.add((o, i))
            out.append((o, i))
    return out


def _rewrite_exists(child, m: ExistsExpr):
    sub = decorrelate(m.plan.v)
    # outputs (and dedup) are irrelevant to row existence
    sub = _strip_wrappers(sub, "EXISTS", drop_project=True, drop_distinct=True)
    sub, eqs, neqs = _strip_correlation(sub)
    if any(u for _, _, u in eqs + neqs):
        raise SqlError(
            "correlation below an aggregate inside EXISTS is not supported"
        )
    if not eqs and not neqs:
        # uncorrelated EXISTS: attach COUNT(*) of the subquery once
        n = f"{m.name}_n"
        agg = Project(
            Aggregate(sub, (), ((n, "size", None),)), ((n, SCol("", n)),)
        )
        out = AttachScalar(child, m.name, Boxed(agg), n)
        op = "=" if m.negated else ">"
        return out, SCmp(op, SCol("", m.name), SLit(0))
    if not eqs:
        raise SqlError(
            "EXISTS correlated only by <> is not supported; add an "
            "equality correlation"
        )
    eq = _dedupe_pairs(eqs)
    _check_outer_available(child, [o for o, _ in eq], "EXISTS subquery")
    if not neqs:
        how = "anti" if m.negated else "semi"
        return (
            Join(
                child,
                sub,
                tuple(o for o, _ in eq),
                tuple(i for _, i in eq),
                how,
            ),
            None,
        )
    # one <> residual: EXISTS(inner: key = outer_key AND c <> outer_c).
    # Group the inner rows by the equality keys with
    # n = NUNIQUE(c), m = MIN(c); then
    #   EXISTS      <=>  key has rows  AND NOT (n == 1 AND m == outer_c)
    #   NOT EXISTS  <=>  key has no rows OR (n == 1 AND m == outer_c)
    nq = _dedupe_pairs(neqs)
    if len(nq) != 1:
        raise SqlError(
            "at most one <> correlation is supported inside EXISTS"
        )
    (no, ni) = nq[0]
    _check_outer_available(child, [no], "EXISTS subquery")
    ncol, mcol = f"{m.name}_n", f"{m.name}_m"

    def make_group(inner):
        return Aggregate(
            inner,
            tuple((i, SCol("", i)) for _, i in eq),
            ((ncol, "nunique", SCol("", ni)), (mcol, "min", SCol("", ni))),
        )

    if not m.negated:
        # semi join on the equality keys, then anti join against the
        # single-value groups whose only value equals the outer column.
        # The inner relation feeds BOTH joins — wrap it in Shared so
        # lowering evaluates it once instead of scanning twice.
        inner = Shared(sub)
        semi = Join(
            child,
            inner,
            tuple(o for o, _ in eq),
            tuple(i for _, i in eq),
            "semi",
        )
        only_one = Filter(make_group(inner), SCmp("=", SCol("", ncol), SLit(1)))
        anti = Join(
            semi,
            only_one,
            tuple(o for o, _ in eq) + (no,),
            tuple(i for _, i in eq) + (mcol,),
            "anti",
        )
        return anti, None
    group = make_group(sub)
    # NOT EXISTS: left join the grouped inner, keep rows with no group
    # or whose single inner value is exactly the outer column's value
    left = Join(
        child,
        group,
        tuple(o for o, _ in eq),
        tuple(i for _, i in eq),
        "left",
    )
    residual = SOr(
        SIsNull(SCol("", ncol)),
        SAnd(
            SCmp("=", SCol("", ncol), SLit(1)),
            SCmp("=", SCol("", mcol), SCol("", no)),
        ),
    )
    return left, residual


def _rewrite_in(child, m: InSubExpr):
    if not isinstance(m.e, SCol):
        raise SqlError(
            "the left side of IN (SELECT ...) must be a plain column"
        )
    sub = decorrelate(m.plan.v)
    # keep the Project (its output is the key); IN is a membership
    # test, so dedup is also droppable
    sub = _strip_wrappers(sub, "IN", drop_distinct=True)
    sub, eqs, neqs = _strip_correlation(sub)
    if neqs:
        raise SqlError("<> correlation inside IN subqueries is not supported")
    if any(u for _, _, u in eqs):
        raise SqlError(
            "correlation below an aggregate inside IN is not supported"
        )
    eq = _dedupe_pairs(eqs)
    if eq:
        sub = _extend_project(sub, [i for _, i in eq])
    _check_outer_available(child, [o for o, _ in eq], "IN subquery")
    how = "anti" if m.negated else "semi"
    return (
        Join(
            child,
            sub,
            (m.e.internal,) + tuple(o for o, _ in eq),
            (m.output,) + tuple(i for _, i in eq),
            how,
        ),
        None,
    )


def _extend_project(plan, extra_cols):
    """Pass correlation key columns through a subquery's root Project."""
    if not isinstance(plan, Project):
        raise SqlError("correlated IN subquery has an unsupported shape")
    outs = plan.outputs + tuple(
        (c, SCol("", c)) for c in extra_cols if c not in {n for n, _ in plan.outputs}
    )
    return Project(plan.child, outs)


def _rewrite_scalar(child, m: SubqueryExpr):
    sub = decorrelate(m.plan.v)
    sub = _strip_wrappers(sub, "scalar")  # DISTINCT changes row counts: reject
    sub, eqs, neqs = _strip_correlation(sub)
    if neqs:
        raise SqlError(
            "<> correlation inside scalar subqueries is not supported"
        )
    if not eqs:
        return (
            AttachScalar(child, m.name, Boxed(sub), m.output),
            SCol("", m.name),
        )
    # correlated scalar aggregate: re-key the aggregate by the inner
    # correlation columns and join the grouped result back in.  Empty
    # groups vanish (inner join), which matches NULL-comparison
    # semantics for MIN/MAX/AVG/SUM predicates; COUNT (which would
    # need 0, not NULL) is rejected.
    if not all(u for _, _, u in eqs):
        raise SqlError(
            "correlated scalar subqueries must correlate inside an "
            "aggregate (SELECT AGG(...) ... WHERE inner = outer)"
        )
    if not (
        isinstance(sub, Project)
        and len(sub.outputs) == 1
        and isinstance(sub.child, Aggregate)
        and not sub.child.keys
    ):
        raise SqlError(
            "correlated scalar subquery must be a single ungrouped "
            "aggregate over the correlated table"
        )
    agg = sub.child
    if any(fn in ("size", "count") for _, fn, _ in agg.aggs):
        raise SqlError(
            "correlated COUNT subqueries are not supported (empty "
            "groups would need COUNT = 0, which the join rewrite drops)"
        )
    eq = _dedupe_pairs(eqs)
    _check_outer_available(child, [o for o, _ in eq], "scalar subquery")
    keyed = Aggregate(
        agg.child, tuple((i, SCol("", i)) for _, i in eq), agg.aggs
    )
    (_, vexpr), = sub.outputs
    proj = Project(
        keyed,
        tuple((i, SCol("", i)) for _, i in eq) + ((m.name, vexpr),),
    )
    joined = Join(
        child,
        proj,
        tuple(o for o, _ in eq),
        tuple(i for _, i in eq),
        "inner",
    )
    return joined, SCol("", m.name)


# ----------------------------------------------------------------------
# rule 1: constant folding
# ----------------------------------------------------------------------
_ARITH = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
}
_CMP = {
    "=": lambda a, b: a == b,
    "<>": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


def _is_num_lit(e) -> bool:
    return isinstance(e, SLit) and isinstance(e.value, (int, float)) and not isinstance(e.value, bool)


def _days(e) -> Optional[int]:
    if isinstance(e, (SDate, SInterval)):
        return e.days
    if isinstance(e, SLit) and isinstance(e.value, int) and not isinstance(e.value, bool):
        return e.value
    return None


def fold_expr_node(n):
    """One-step fold of a node whose children are already folded."""
    if isinstance(n, SBin):
        a, b = n.a, n.b
        if _is_num_lit(a) and _is_num_lit(b):
            if n.op == "/" and b.value == 0:
                return n
            return SLit(_ARITH[n.op](a.value, b.value))
        if isinstance(a, SDate) and n.op in ("+", "-"):
            nb = _days(b)
            if nb is not None and not isinstance(b, SDate):
                return SDate(a.days + nb if n.op == "+" else a.days - nb)
            if isinstance(b, SDate) and n.op == "-":
                return SLit(a.days - b.days)
        if isinstance(b, SDate) and n.op == "+":
            na = _days(a)
            if na is not None and not isinstance(a, SDate):
                return SDate(b.days + na)
        if isinstance(a, SInterval) and isinstance(b, SInterval):
            return SInterval(a.days + b.days if n.op == "+" else a.days - b.days)
    elif isinstance(n, SCmp):
        a, b = n.a, n.b
        if _is_num_lit(a) and _is_num_lit(b):
            return SLit(bool(_CMP[n.op](a.value, b.value)))
        if isinstance(a, SDate) and isinstance(b, SDate):
            return SLit(bool(_CMP[n.op](a.days, b.days)))
        if (
            isinstance(a, SLit) and isinstance(b, SLit)
            and isinstance(a.value, str) and isinstance(b.value, str)
        ):
            return SLit(bool(_CMP[n.op](a.value, b.value)))
    elif isinstance(n, SAnd):
        if n.a == SLit(True):
            return n.b
        if n.b == SLit(True):
            return n.a
        if SLit(False) in (n.a, n.b):
            return SLit(False)
    elif isinstance(n, SOr):
        if n.a == SLit(False):
            return n.b
        if n.b == SLit(False):
            return n.a
        if SLit(True) in (n.a, n.b):
            return SLit(True)
    elif isinstance(n, SNot):
        if isinstance(n.a, SLit) and isinstance(n.a.value, bool):
            return SLit(not n.a.value)
    elif isinstance(n, SCase):
        # drop WHEN branches with constant-false conditions
        whens = tuple((c, r) for c, r in n.whens if c != SLit(False))
        if whens != n.whens:
            if not whens:
                return n.default
            return SCase(whens, n.default)
    return n


def fold_expr(e):
    return transform(e, fold_expr_node)


def fold_constants(node):
    """Fold every expression embedded in the plan."""
    if isinstance(node, Filter):
        return Filter(fold_constants(node.child), fold_expr(node.pred))
    if isinstance(node, Project):
        return Project(
            fold_constants(node.child),
            tuple((n, fold_expr(e)) for n, e in node.outputs),
        )
    if isinstance(node, Aggregate):
        return Aggregate(
            fold_constants(node.child),
            tuple((n, fold_expr(e)) for n, e in node.keys),
            tuple(
                (n, fn, fold_expr(e) if e is not None else None)
                for n, fn, e in node.aggs
            ),
        )
    if isinstance(node, Join):
        return dataclasses.replace(
            node, left=fold_constants(node.left), right=fold_constants(node.right)
        )
    if isinstance(node, (Sort, Limit, Distinct, Shared)):
        return dataclasses.replace(node, child=fold_constants(node.child))
    if isinstance(node, AttachScalar):
        return dataclasses.replace(
            node,
            child=fold_constants(node.child),
            sub=Boxed(fold_constants(node.sub.v)),
        )
    return node


# ----------------------------------------------------------------------
# rule 2: filter pushdown
# ----------------------------------------------------------------------
def push_filters(node):
    if isinstance(node, Filter):
        conjuncts = split_conjuncts(node.pred)
        child = node.child
        # merge stacked filters before pushing
        while isinstance(child, Filter):
            conjuncts += split_conjuncts(child.pred)
            child = child.child
        conjuncts = [c for c in conjuncts if c != SLit(True)]
        if not conjuncts:
            return push_filters(child)
        return _push_into(child, conjuncts)
    if isinstance(node, Join):
        return dataclasses.replace(
            node, left=push_filters(node.left), right=push_filters(node.right)
        )
    if isinstance(node, (Project, Aggregate, Sort, Limit, Distinct, Shared)):
        return dataclasses.replace(node, child=push_filters(node.child))
    if isinstance(node, AttachScalar):
        return dataclasses.replace(
            node,
            child=push_filters(node.child),
            sub=Boxed(push_filters(node.sub.v)),
        )
    return node


def _push_into(child, conjuncts):
    """Push a list of conjuncts into ``child``; returns the new subtree
    (residual conjuncts wrap it in a Filter)."""
    if isinstance(child, Join):
        lcols, rcols = node_columns(child.left), node_columns(child.right)
        to_left, to_right, stay = [], [], []
        for c in conjuncts:
            cols = expr_columns(c)
            if cols <= lcols:
                to_left.append(c)
            elif cols <= rcols and child.how == "inner":
                to_right.append(c)
            else:
                stay.append(c)
        left = Filter(child.left, conjoin(to_left)) if to_left else child.left
        right = Filter(child.right, conjoin(to_right)) if to_right else child.right
        out = Join(
            push_filters(left),
            push_filters(right),
            child.left_keys,
            child.right_keys,
            child.how,
        )
        return Filter(out, conjoin(stay)) if stay else out
    if isinstance(child, Aggregate):
        # a conjunct over plain-column group keys commutes with grouping
        plain_keys = {
            n for n, e in child.keys if isinstance(e, SCol) and e.internal == n
        }
        below, stay = [], []
        for c in conjuncts:
            (below if expr_columns(c) <= plain_keys else stay).append(c)
        out = child
        if below:
            out = dataclasses.replace(
                child, child=Filter(child.child, conjoin(below))
            )
        out = dataclasses.replace(out, child=push_filters(out.child))
        return Filter(out, conjoin(stay)) if stay else out
    if isinstance(child, Distinct):
        # a filter over the deduped columns commutes with dedup
        return Distinct(_push_into(child.child, conjuncts))
    if isinstance(child, Project):
        # A conjunct over Project outputs rewrites to the defining
        # expressions and commutes with the projection — this is what
        # lets predicates keep sinking through derived tables (q15's
        # revenue filter used to stop at the qualifying Project and
        # re-scan the whole derived output).
        outmap = {n: e for n, e in child.outputs}
        below, stay = [], []
        for c in conjuncts:
            if subquery_markers(c) or not expr_columns(c) <= set(outmap):
                stay.append(c)
            else:
                below.append(_substitute_outputs(c, outmap))
        inner = (
            _push_into(child.child, below)
            if below
            else push_filters(child.child)
        )
        out = Project(inner, child.outputs)
        return Filter(out, conjoin(stay)) if stay else out
    if isinstance(child, AttachScalar):
        below, stay = [], []
        for c in conjuncts:
            (stay if child.name in expr_columns(c) else below).append(c)
        inner = (
            _push_into(child.child, below)
            if below
            else push_filters(child.child)
        )
        out = dataclasses.replace(
            child, child=inner, sub=Boxed(push_filters(child.sub.v))
        )
        return Filter(out, conjoin(stay)) if stay else out
    child = push_filters(child)
    return Filter(child, conjoin(conjuncts))


def _substitute_outputs(e, outmap):
    """Rewrite output-column references to their defining expressions."""
    return transform(
        e,
        lambda n: outmap[n.internal]
        if isinstance(n, SCol) and n.internal in outmap
        else n,
    )


# ----------------------------------------------------------------------
# rule 2b: sargable conjuncts into store-backed scans
# ----------------------------------------------------------------------
def push_scan_predicates(node, store_tables):
    """Move sargable Filter conjuncts into Scans of store-backed tables.

    A sargable conjunct compares one scanned column against constants
    (``col <op> literal``, ``BETWEEN``, ``IN (literals, ...)``,
    ``IS [NOT] NULL``, ``LIKE 'prefix%'``).  The store scan applies it
    exactly — zone maps skip whole chunks (null counts answer IS NULL,
    the sorted dictionary reduces a LIKE prefix to a code range), then
    a host-side row filter — so the conjunct is *removed* from the
    plan rather than duplicated.  Everything else (general LIKE,
    arithmetic over columns, OR trees) stays as a residual Filter
    above the scan.
    """
    if isinstance(node, Filter):
        child = push_scan_predicates(node.child, store_tables)
        if isinstance(child, Scan) and child.table in store_tables:
            push, keep = [], []
            for c in split_conjuncts(node.pred):
                (push if _sargable(c, child) else keep).append(c)
            if push:
                child = dataclasses.replace(
                    child, predicates=child.predicates + tuple(push)
                )
            return Filter(child, conjoin(keep)) if keep else child
        return Filter(child, node.pred)
    if isinstance(node, Join):
        return dataclasses.replace(
            node,
            left=push_scan_predicates(node.left, store_tables),
            right=push_scan_predicates(node.right, store_tables),
        )
    if isinstance(node, (Project, Aggregate, Sort, Limit, Distinct, Shared)):
        return dataclasses.replace(
            node, child=push_scan_predicates(node.child, store_tables)
        )
    if isinstance(node, AttachScalar):
        return dataclasses.replace(
            node,
            child=push_scan_predicates(node.child, store_tables),
            sub=Boxed(push_scan_predicates(node.sub.v, store_tables)),
        )
    return node


def _is_scan_const(e) -> bool:
    if isinstance(e, SDate):
        return True
    return (
        isinstance(e, SLit)
        and e.value is not None
        and not isinstance(e.value, bool)
    )


def _sargable(c, scan: Scan) -> bool:
    cols = {f"{scan.alias}.{col}" for col in scan.columns}

    def scan_col(e) -> bool:
        return isinstance(e, SCol) and e.internal in cols

    if isinstance(c, SCmp):
        return (scan_col(c.a) and _is_scan_const(c.b)) or (
            scan_col(c.b) and _is_scan_const(c.a)
        )
    if isinstance(c, SBetween) and not c.negated:
        return scan_col(c.e) and _is_scan_const(c.lo) and _is_scan_const(c.hi)
    if isinstance(c, SIn) and not c.negated:
        return scan_col(c.e) and all(_is_scan_const(v) for v in c.values)
    if isinstance(c, SIsNull):
        return scan_col(c.e)
    if isinstance(c, SLike) and not c.negated:
        return scan_col(c.e) and like_prefix(c.pattern) is not None
    return False


# ----------------------------------------------------------------------
# rule 3: projection pruning
# ----------------------------------------------------------------------
def prune_projections(node, required: Optional[Set[str]] = None):
    """Narrow Scans to the columns actually referenced above them.

    ``required=None`` means "everything" (the root, and below nodes that
    need their child intact).

    Runs in two passes so ``Shared`` subplans prune consistently: the
    first records the union of the column sets every consumer demands
    from each Shared node, the second rewrites using those unions, so
    equal Shared wrappers stay equal (and lowering still evaluates the
    shared subtree once)."""
    shared_req: dict = {}
    _prune(node, required, shared_req, record=True)
    return _prune(node, required, shared_req, record=False)


def _prune(node, required: Optional[Set[str]], shared_req: dict, record: bool):
    if isinstance(node, Project):
        outputs = node.outputs
        if required is not None:
            # Narrow the projection to what parents actually consume —
            # the decorrelated semi/anti-join right sides (IN-subquery
            # Projects, derived tables under joins) shrink to their
            # join keys before the build (ROADMAP open item).
            kept = tuple((n, e) for n, e in outputs if n in required)
            if kept:
                outputs = kept
        need = set()
        for _, e in outputs:
            need |= expr_columns(e)
        return Project(_prune(node.child, need, shared_req, record), outputs)
    if isinstance(node, Sort):
        # sort keys are consumed here even if no parent needs them
        need = (
            None if required is None
            else required | {n for n, _ in node.keys}
        )
        return dataclasses.replace(
            node, child=_prune(node.child, need, shared_req, record)
        )
    if isinstance(node, Limit):
        return dataclasses.replace(
            node, child=_prune(node.child, required, shared_req, record)
        )
    if isinstance(node, Distinct):
        # dedup semantics depend on every child column: keep them all
        return Distinct(_prune(node.child, None, shared_req, record))
    if isinstance(node, Shared):
        if record:
            have = shared_req.get(node, frozenset())
            if required is None or have is None:
                shared_req[node] = None
            else:
                shared_req[node] = frozenset(have) | frozenset(required)
            _prune(node.child, shared_req[node], shared_req, record)
            return node
        need = shared_req.get(node, None)
        return Shared(_prune(node.child, need, shared_req, record))
    if isinstance(node, AttachScalar):
        need = None if required is None else required - {node.name}
        return dataclasses.replace(
            node,
            child=_prune(node.child, need, shared_req, record),
            sub=Boxed(_prune(node.sub.v, None, shared_req, record)),
        )
    if isinstance(node, Filter):
        need = None if required is None else required | expr_columns(node.pred)
        return Filter(_prune(node.child, need, shared_req, record), node.pred)
    if isinstance(node, Aggregate):
        aggs = node.aggs
        if required is not None:
            # drop aggregate expressions no parent consumes — a Project
            # that keeps half the aggregates no longer computes them all
            # (group keys always stay: they define the grouping)
            kept = tuple(a for a in aggs if a[0] in required)
            if kept or not aggs:
                aggs = kept
        need = set()
        for _, e in node.keys:
            need |= expr_columns(e)
        for _, _, e in aggs:
            if e is not None:
                need |= expr_columns(e)
        return Aggregate(
            _prune(node.child, need, shared_req, record), node.keys, aggs
        )
    if isinstance(node, Join):
        need = (
            None
            if required is None
            else required | set(node.left_keys) | set(node.right_keys)
        )
        lcols, rcols = node_columns(node.left), node_columns(node.right)
        lneed = None if need is None else need & lcols
        rneed = None if need is None else need & rcols
        return Join(
            _prune(node.left, lneed, shared_req, record),
            _prune(node.right, rneed, shared_req, record),
            node.left_keys,
            node.right_keys,
            node.how,
        )
    if isinstance(node, Scan):
        if required is None:
            return node
        keep = tuple(
            c for c in node.columns if f"{node.alias}.{c}" in required
        )
        return dataclasses.replace(node, columns=keep)
    raise TypeError(f"unknown plan node {type(node).__name__}")
