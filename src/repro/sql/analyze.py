"""EXPLAIN ANALYZE: execute a plan with per-operator accounting.

``repro.sql.execute(query, scope, explain="analyze")`` runs the
optimized plan op-by-op with tracing forced on and an active collector
(``lower.ANALYZE_COLLECTOR``), then renders the plan tree annotated
with per-operator wall time (total and self), input/output row counts,
bytes materialized, and — for joins — the algorithm the stats-driven
picker actually chose (mined from the ``core.join`` span recorded
under each ``sql.exec.Join`` span).

The compiled whole-plan path is bypassed for the analyzed execution:
one fused XLA program has no per-operator boundaries to account.  Use
``obs.metrics`` / ``sql.compile.STATS`` for compiled-path phase timing
(trace/compile/execute + cache hit/miss).

Wall times settle async dispatch per node (``block_until_ready``), so
an analyzed run is slower than production execution — it buys honest
attribution, not a benchmark number.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro import obs

from .plan import (
    Aggregate,
    AttachScalar,
    Distinct,
    Filter,
    Join,
    Limit,
    Project,
    Shared,
    Sort,
    node_label,
)

__all__ = ["AnalyzeResult", "NodeStats", "run_analyze"]

_JOIN_ATTRS = ("algorithm", "build_rows", "probe_rows", "how")


@dataclasses.dataclass
class NodeStats:
    wall_ns: int = 0
    rows_out: Optional[int] = None
    rows_in: Optional[int] = None
    bytes_out: int = 0
    materialized: bool = True  # False: RowView (selection vectors only)
    span_id: int = 0
    calls: int = 0
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


class Collector:
    """Accumulates per-plan-node execution facts during lowering."""

    def __init__(self) -> None:
        self.stats: Dict[int, NodeStats] = {}  # id(node) -> stats

    def block(self, frame) -> None:
        """Best-effort settle of async dispatch so the node's wall time
        covers its compute, not just its dispatch."""
        try:
            import jax

            for arr in (frame._itensor, frame._ftensor):
                if arr is not None:
                    jax.block_until_ready(arr)
            view = frame._view
            if view is not None:
                if view.rowmat is not None:
                    jax.block_until_ready(view.rowmat)
                for b in view.blocks:
                    jax.block_until_ready(b.itensor)
                    jax.block_until_ready(b.ftensor)
        except Exception:
            pass

    def record(
        self, node, wall_ns: int, out, span_id: int, rows_in=None
    ) -> None:
        st = self.stats.setdefault(id(node), NodeStats())
        st.calls += 1
        if st.calls > 1:  # memoized Shared re-request: keep first run
            return
        st.wall_ns = wall_ns
        st.span_id = span_id
        st.rows_in = rows_in
        st.rows_out = getattr(out, "nrows", None)
        try:
            if getattr(out, "is_view", False):
                st.materialized = False
                rowmat = out._view.rowmat
                st.bytes_out = int(rowmat.nbytes) if rowmat is not None else 0
            else:
                st.bytes_out = int(out._itensor.nbytes) + int(
                    out._ftensor.nbytes
                )
        except Exception:
            st.bytes_out = 0

    def finalize(self, records) -> None:
        """Mine recorded spans: attach each ``core.join`` span's
        algorithm decision to the nearest enclosing plan-node span."""
        by_id = {s.span_id: s for s in records}
        node_of_span = {
            st.span_id: key
            for key, st in self.stats.items()
            if st.span_id
        }
        for s in records:
            if s.name != "core.join" or not s.attrs:
                continue
            p = s.parent_id
            while p:
                key = node_of_span.get(p)
                if key is not None:
                    extra = self.stats[key].extra
                    for k in _JOIN_ATTRS:
                        if k in s.attrs and k not in extra:
                            extra[k] = s.attrs[k]
                    break
                parent = by_id.get(p)
                p = parent.parent_id if parent is not None else 0


def _fmt_bytes(n: int) -> str:
    if n >= 1 << 30:
        return f"{n / (1 << 30):.2f}GiB"
    if n >= 1 << 20:
        return f"{n / (1 << 20):.2f}MiB"
    if n >= 1 << 10:
        return f"{n / (1 << 10):.1f}KiB"
    return f"{n}B"


def _fmt_ms(ns: int) -> str:
    return f"{ns / 1e6:.2f}ms"


class AnalyzeResult:
    """The frame plus the annotated plan; ``str()`` renders the tree."""

    def __init__(self, frame, plan, collector: Collector, wall_ns: int):
        self.frame = frame
        self.plan = plan
        self.stats = collector.stats
        self.wall_ns = wall_ns

    # -- rendering -------------------------------------------------------
    def _children(self, node):
        if isinstance(node, Join):
            return [node.left, node.right]
        if isinstance(node, AttachScalar):
            return [node.child, node.sub.v]
        if isinstance(
            node,
            (Filter, Aggregate, Project, Sort, Limit, Distinct, Shared),
        ):
            return [node.child]
        return []

    def _annotation(self, node) -> str:
        st = self.stats.get(id(node))
        if st is None:
            return "[not executed]"
        kids = [
            self.stats.get(id(c))
            for c in self._children(node)
            if self.stats.get(id(c)) is not None
        ]
        self_ns = max(st.wall_ns - sum(k.wall_ns for k in kids), 0)
        parts = [f"time={_fmt_ms(st.wall_ns)}", f"self={_fmt_ms(self_ns)}"]
        if st.rows_in is not None:
            parts.append(f"rows_in={st.rows_in}")
        if st.rows_out is not None:
            parts.append(f"rows={st.rows_out}")
        tag = "" if st.materialized else " (view)"
        parts.append(f"bytes={_fmt_bytes(st.bytes_out)}{tag}")
        if "algorithm" in st.extra:
            parts.append(f"algo={st.extra['algorithm']}")
            if "build_rows" in st.extra:
                parts.append(f"build={st.extra['build_rows']}")
        if st.calls > 1:
            parts.append(f"reused x{st.calls - 1}")
        return "[" + " ".join(parts) + "]"

    def _render(self, node, indent: int) -> str:
        pad = "  " * indent
        line = f"{pad}{node_label(node)}  {self._annotation(node)}"
        return "\n".join(
            [line]
            + [self._render(c, indent + 1) for c in self._children(node)]
        )

    def render(self) -> str:
        head = (
            f"== EXPLAIN ANALYZE ==  total {_fmt_ms(self.wall_ns)}, "
            f"{self.frame.nrows} row(s) out"
        )
        return head + "\n" + self._render(self.plan, 0)

    __str__ = render

    def __repr__(self) -> str:
        return self.render()

    # -- machine-readable -----------------------------------------------
    def to_dict(self) -> Dict:
        def walk(node):
            st = self.stats.get(id(node))
            d = {
                "node": type(node).__name__,
                "label": node_label(node),
                "children": [walk(c) for c in self._children(node)],
            }
            if st is not None:
                d.update(
                    wall_ms=st.wall_ns / 1e6,
                    rows_out=st.rows_out,
                    rows_in=st.rows_in,
                    bytes_out=st.bytes_out,
                    materialized=st.materialized,
                    **st.extra,
                )
            return d

        return {"total_ms": self.wall_ns / 1e6, "plan": walk(self.plan)}


def run_analyze(plan, frames) -> AnalyzeResult:
    """Execute ``plan`` op-by-op with the collector active and tracing
    forced on; restores ``CONFIG.tracing`` after."""
    import time

    from repro.core.config import CONFIG

    from .lower import ANALYZE_COLLECTOR, lower_plan

    coll = Collector()
    saved = CONFIG.tracing
    if saved == "off":
        CONFIG.tracing = "on"
    mark = obs.mark_ns()
    token = ANALYZE_COLLECTOR.set(coll)
    t0 = time.perf_counter_ns()
    try:
        frame = lower_plan(plan, frames)
    finally:
        ANALYZE_COLLECTOR.reset(token)
        CONFIG.tracing = saved
    wall_ns = time.perf_counter_ns() - t0
    coll.finalize(obs.spans(since_ns=mark))
    return AnalyzeResult(frame, plan, coll, wall_ns)
