"""SQL tokenizer, expression AST, and recursive-descent parser.

Covers the TPC-H SELECT dialect: projections with aliases, arithmetic,
comparisons, AND/OR/NOT, IN, BETWEEN, LIKE, IS [NOT] NULL, CASE WHEN,
EXTRACT(YEAR|MONTH|DAY FROM e), DATE/INTERVAL literals, aggregate calls
(COUNT/SUM/AVG/MIN/MAX, COUNT(*), COUNT(DISTINCT c)), SELECT DISTINCT,
comma-separated FROM lists with aliases, derived tables
(``FROM (SELECT ...) alias``), [INNER|LEFT] JOIN ... ON, WHERE,
GROUP BY, HAVING, ORDER BY [ASC|DESC], LIMIT, and subqueries: scalar
``(SELECT ...)`` in expressions, ``[NOT] IN (SELECT ...)`` and
``[NOT] EXISTS (SELECT ...)`` predicates (correlation is resolved by
the planner, decorrelation by the optimizer).

All AST nodes are frozen dataclasses: structural equality/hash are used
by the planner to deduplicate aggregate expressions and by tests for
plan comparison.  Nested SELECTs are wrapped in ``Boxed`` — a plain
(non-dataclass) holder with value equality — so the generic
``walk``/``transform`` helpers do not descend across scope boundaries.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

import numpy as np


class SqlError(ValueError):
    """Parse/plan/lowering error with a human-readable message."""


class Boxed:
    """Opaque holder for a nested SELECT (or a planned subquery tree).

    Not a dataclass on purpose: ``walk``/``transform``/``expr_columns``
    skip non-dataclass field values, so an outer-scope rewrite never
    descends into a subquery's own expressions.  Equality and hash
    delegate to the wrapped value so AST equality still works."""

    __slots__ = ("v",)

    def __init__(self, v):
        self.v = v

    def __eq__(self, other):
        return isinstance(other, Boxed) and self.v == other.v

    def __ne__(self, other):
        return not self.__eq__(other)

    def __hash__(self):
        return hash(self.v)

    def __repr__(self):
        return f"Boxed({self.v!r})"


# ----------------------------------------------------------------------
# expression AST
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SCol:
    table: Optional[str]  # alias qualifier; "" = resolved output-name ref
    name: str

    @property
    def internal(self) -> str:
        if self.table:
            return f"{self.table}.{self.name}"
        return self.name


@dataclasses.dataclass(frozen=True)
class SLit:
    value: object  # int | float | str | bool


@dataclasses.dataclass(frozen=True)
class SDate:
    days: int  # epoch days

    @property
    def text(self) -> str:
        return str(np.datetime64(self.days, "D"))


@dataclasses.dataclass(frozen=True)
class SInterval:
    days: int


@dataclasses.dataclass(frozen=True)
class SBin:
    op: str  # + - * /
    a: object
    b: object


@dataclasses.dataclass(frozen=True)
class SCmp:
    op: str  # = <> < <= > >=
    a: object
    b: object


@dataclasses.dataclass(frozen=True)
class SAnd:
    a: object
    b: object


@dataclasses.dataclass(frozen=True)
class SOr:
    a: object
    b: object


@dataclasses.dataclass(frozen=True)
class SNot:
    a: object


@dataclasses.dataclass(frozen=True)
class SIn:
    e: object
    values: Tuple[object, ...]  # literal nodes
    negated: bool = False


@dataclasses.dataclass(frozen=True)
class SBetween:
    e: object
    lo: object
    hi: object
    negated: bool = False


@dataclasses.dataclass(frozen=True)
class SLike:
    e: object
    pattern: str
    negated: bool = False


@dataclasses.dataclass(frozen=True)
class SIsNull:
    e: object
    negated: bool = False


def like_prefix(pattern: str) -> Optional[str]:
    """The literal prefix of a sargable ``LIKE 'prefix%'`` pattern, or
    None when the pattern is not a pure prefix match (wildcards other
    than one trailing ``%``)."""
    if not pattern.endswith("%"):
        return None
    body = pattern[:-1]
    if "%" in body or "_" in body:
        return None
    return body


@dataclasses.dataclass(frozen=True)
class SCase:
    whens: Tuple[Tuple[object, object], ...]
    default: object


@dataclasses.dataclass(frozen=True)
class SExtract:
    field: str  # year | month | day
    e: object


AGG_FUNCS = ("count", "sum", "avg", "min", "max")

# non-aggregate functions the engine and the oracle both implement
SCALAR_FUNCS = ("abs", "sqrt", "floor", "exp", "log", "sin", "cos", "substring")


@dataclasses.dataclass(frozen=True)
class SFunc:
    name: str  # lowercase
    args: Tuple[object, ...]
    distinct: bool = False

    @property
    def is_aggregate(self) -> bool:
        return self.name in AGG_FUNCS


@dataclasses.dataclass(frozen=True)
class SStar:
    pass


@dataclasses.dataclass(frozen=True)
class SSub:
    """Scalar subquery ``(SELECT ...)`` used as an expression."""

    select: Boxed  # Boxed[Select]


@dataclasses.dataclass(frozen=True)
class SInSub:
    """``e [NOT] IN (SELECT ...)``."""

    e: object
    select: Boxed  # Boxed[Select]
    negated: bool = False


@dataclasses.dataclass(frozen=True)
class SExists:
    """``[NOT] EXISTS (SELECT ...)``."""

    select: Boxed  # Boxed[Select]
    negated: bool = False


# ----------------------------------------------------------------------
# statement AST
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FromItem:
    table: str  # "" for a derived table
    alias: str
    sub: Optional[Boxed] = None  # Boxed[Select] for derived tables


@dataclasses.dataclass(frozen=True)
class JoinClause:
    item: FromItem
    how: str  # inner | left
    on: object


@dataclasses.dataclass(frozen=True)
class Select:
    columns: Tuple[Tuple[object, Optional[str]], ...]  # (expr, alias)
    from_items: Tuple[FromItem, ...]
    joins: Tuple[JoinClause, ...]
    where: Optional[object]
    group_by: Tuple[object, ...]
    having: Optional[object]
    order_by: Tuple[Tuple[object, bool], ...]  # (expr, ascending)
    limit: Optional[int]
    distinct: bool = False


# ----------------------------------------------------------------------
# tokenizer
# ----------------------------------------------------------------------
_TOKEN_RE = re.compile(
    r"""
      (?P<ws>\s+|--[^\n]*)
    | (?P<num>\d+\.\d*|\.\d+|\d+)
    | (?P<str>'(?:[^']|'')*')
    | (?P<op><=|>=|<>|!=|[=<>+\-*/(),.])
    | (?P<name>[A-Za-z_][A-Za-z_0-9]*)
    """,
    re.VERBOSE,
)

# "year"/"month"/"day" are deliberately NOT reserved: they are common
# column aliases.  INTERVAL and EXTRACT match them contextually.
KEYWORDS = {
    "select", "from", "where", "group", "by", "having", "order", "limit",
    "as", "and", "or", "not", "in", "between", "like", "is", "null",
    "case", "when", "then", "else", "end", "extract", "date", "interval",
    "join", "inner", "left", "outer", "on", "exists",
    "asc", "desc", "distinct", "true", "false",
}

_DATE_UNITS = ("year", "month", "day")


@dataclasses.dataclass
class Token:
    kind: str  # num | str | op | name | kw | end
    text: str
    pos: int


def tokenize(sql: str):
    out = []
    i = 0
    while i < len(sql):
        m = _TOKEN_RE.match(sql, i)
        if not m:
            raise SqlError(f"unexpected character {sql[i]!r} at position {i}")
        i = m.end()
        kind = m.lastgroup
        if kind == "ws":
            continue
        text = m.group()
        if kind == "name" and text.lower() in KEYWORDS:
            out.append(Token("kw", text.lower(), m.start()))
        else:
            out.append(Token(kind, text, m.start()))
    out.append(Token("end", "", len(sql)))
    return out


# ----------------------------------------------------------------------
# parser
# ----------------------------------------------------------------------
class _Parser:
    def __init__(self, sql: str):
        self.sql = sql
        self.toks = tokenize(sql)
        self.i = 0

    # -------- token plumbing --------
    @property
    def cur(self) -> Token:
        return self.toks[self.i]

    def advance(self) -> Token:
        t = self.cur
        self.i += 1
        return t

    def at_kw(self, *kws: str) -> bool:
        return self.cur.kind == "kw" and self.cur.text in kws

    def accept_kw(self, *kws: str) -> bool:
        if self.at_kw(*kws):
            self.advance()
            return True
        return False

    def expect_kw(self, kw: str) -> None:
        if not self.accept_kw(kw):
            self.fail(f"expected {kw.upper()}")

    def accept_op(self, op: str) -> bool:
        if self.cur.kind == "op" and self.cur.text == op:
            self.advance()
            return True
        return False

    def expect_op(self, op: str) -> None:
        if not self.accept_op(op):
            self.fail(f"expected {op!r}")

    def fail(self, msg: str):
        t = self.cur
        got = t.text or "<end of input>"
        raise SqlError(f"{msg} at position {t.pos} (got {got!r})")

    # -------- grammar --------
    def parse(self) -> Select:
        self.expect_kw("select")
        sel = self.select_body()
        if self.cur.kind != "end":
            self.fail("trailing input after query")
        return sel

    def select_body(self) -> Select:
        distinct = self.accept_kw("distinct")
        columns = [self.select_item()]
        while self.accept_op(","):
            columns.append(self.select_item())
        self.expect_kw("from")
        from_items = [self.from_item()]
        joins = []
        while True:
            if self.accept_op(","):
                from_items.append(self.from_item())
            elif self.at_kw("join", "inner", "left"):
                joins.append(self.join_clause())
            else:
                break
        where = self.expr() if self.accept_kw("where") else None
        group_by: Tuple = ()
        if self.accept_kw("group"):
            self.expect_kw("by")
            keys = [self.expr()]
            while self.accept_op(","):
                keys.append(self.expr())
            group_by = tuple(keys)
        having = self.expr() if self.accept_kw("having") else None
        order_by = []
        if self.accept_kw("order"):
            self.expect_kw("by")
            order_by.append(self.order_item())
            while self.accept_op(","):
                order_by.append(self.order_item())
        limit = None
        if self.accept_kw("limit"):
            t = self.advance()
            if t.kind != "num" or "." in t.text:
                raise SqlError(f"LIMIT expects an integer at position {t.pos}")
            limit = int(t.text)
        return Select(
            tuple(columns), tuple(from_items), tuple(joins), where,
            group_by, having, tuple(order_by), limit, distinct,
        )

    def subselect(self) -> Boxed:
        """Parse ``SELECT ...`` (the opening keyword already expected by
        the caller) and box it against outer-scope tree rewrites."""
        self.expect_kw("select")
        return Boxed(self.select_body())

    def select_item(self):
        if self.accept_op("*"):
            return (SStar(), None)
        e = self.expr()
        alias = None
        if self.accept_kw("as"):
            alias = self.identifier("alias")
        elif self.cur.kind == "name":  # bare alias
            alias = self.advance().text
        return (e, alias)

    def identifier(self, what: str) -> str:
        if self.cur.kind != "name":
            self.fail(f"expected {what}")
        return self.advance().text

    def date_unit(self) -> str:
        if self.cur.kind == "name" and self.cur.text.lower() in _DATE_UNITS:
            return self.advance().text.lower()
        self.fail("expected YEAR, MONTH or DAY")

    def from_item(self) -> FromItem:
        if self.accept_op("("):  # derived table: (SELECT ...) alias
            sub = self.subselect()
            self.expect_op(")")
            self.accept_kw("as")
            alias = self.identifier("derived-table alias")
            return FromItem("", alias, sub)
        table = self.identifier("table name")
        alias = table
        if self.accept_kw("as"):
            alias = self.identifier("alias")
        elif self.cur.kind == "name":
            alias = self.advance().text
        return FromItem(table, alias)

    def join_clause(self) -> JoinClause:
        how = "inner"
        if self.accept_kw("left"):
            self.accept_kw("outer")
            how = "left"
        else:
            self.accept_kw("inner")
        self.expect_kw("join")
        item = self.from_item()
        self.expect_kw("on")
        return JoinClause(item, how, self.expr())

    def order_item(self):
        e = self.expr()
        asc = True
        if self.accept_kw("desc"):
            asc = False
        else:
            self.accept_kw("asc")
        return (e, asc)

    # -------- expressions (precedence climbing) --------
    def expr(self):
        return self.or_expr()

    def or_expr(self):
        e = self.and_expr()
        while self.accept_kw("or"):
            e = SOr(e, self.and_expr())
        return e

    def and_expr(self):
        e = self.not_expr()
        while self.accept_kw("and"):
            e = SAnd(e, self.not_expr())
        return e

    def not_expr(self):
        if self.accept_kw("not"):
            return SNot(self.not_expr())
        return self.predicate()

    def predicate(self):
        e = self.additive()
        negated = self.accept_kw("not")
        if self.accept_kw("in"):
            self.expect_op("(")
            if self.at_kw("select"):
                sub = self.subselect()
                self.expect_op(")")
                return SInSub(e, sub, negated)
            vals = [self.additive()]
            while self.accept_op(","):
                vals.append(self.additive())
            self.expect_op(")")
            return SIn(e, tuple(vals), negated)
        if self.accept_kw("between"):
            lo = self.additive()
            self.expect_kw("and")
            hi = self.additive()
            return SBetween(e, lo, hi, negated)
        if self.accept_kw("like"):
            t = self.advance()
            if t.kind != "str":
                raise SqlError(f"LIKE expects a string pattern at position {t.pos}")
            return SLike(e, _unquote(t.text), negated)
        if negated:
            self.fail("expected IN, BETWEEN or LIKE after NOT")
        if self.accept_kw("is"):
            neg = self.accept_kw("not")
            self.expect_kw("null")
            return SIsNull(e, neg)
        for op in ("<=", ">=", "<>", "!=", "=", "<", ">"):
            if self.accept_op(op):
                rhs = self.additive()
                return SCmp("<>" if op == "!=" else op, e, rhs)
        return e

    def additive(self):
        e = self.multiplicative()
        while True:
            if self.accept_op("+"):
                e = SBin("+", e, self.multiplicative())
            elif self.accept_op("-"):
                e = SBin("-", e, self.multiplicative())
            else:
                return e

    def multiplicative(self):
        e = self.unary()
        while True:
            if self.accept_op("*"):
                e = SBin("*", e, self.unary())
            elif self.accept_op("/"):
                e = SBin("/", e, self.unary())
            else:
                return e

    def unary(self):
        if self.accept_op("-"):
            inner = self.unary()
            if isinstance(inner, SLit) and isinstance(inner.value, (int, float)):
                return SLit(-inner.value)
            return SBin("-", SLit(0), inner)
        return self.primary()

    def primary(self):
        t = self.cur
        if self.accept_op("("):
            if self.at_kw("select"):  # scalar subquery
                sub = self.subselect()
                self.expect_op(")")
                return SSub(sub)
            e = self.expr()
            self.expect_op(")")
            return e
        if self.accept_kw("exists"):
            self.expect_op("(")
            sub = self.subselect()
            self.expect_op(")")
            return SExists(sub)
        if t.kind == "num":
            self.advance()
            return SLit(float(t.text) if "." in t.text else int(t.text))
        if t.kind == "str":
            self.advance()
            return SLit(_unquote(t.text))
        if self.accept_kw("true"):
            return SLit(True)
        if self.accept_kw("false"):
            return SLit(False)
        if self.accept_kw("date"):
            s = self.advance()
            if s.kind != "str":
                raise SqlError(f"DATE expects a 'YYYY-MM-DD' string at position {s.pos}")
            try:
                days = int(np.datetime64(_unquote(s.text), "D").astype(np.int64))
            except ValueError as e:
                raise SqlError(f"bad DATE literal {s.text} at position {s.pos}") from e
            return SDate(days)
        if self.accept_kw("interval"):
            s = self.advance()
            if s.kind != "str":
                raise SqlError(f"INTERVAL expects a quoted count at position {s.pos}")
            n = int(_unquote(s.text))
            unit = self.date_unit()
            if unit != "day":
                # calendar month/year arithmetic is NOT a fixed day
                # count; a 30/365-day approximation would give
                # plausible-but-wrong dates that every execution leg
                # agrees on, so refuse instead.
                raise SqlError(
                    f"INTERVAL ... {unit.upper()} is not supported (calendar "
                    f"arithmetic); use an explicit DATE literal or DAY units"
                )
            return SInterval(n)
        if self.accept_kw("case"):
            return self.case_expr()
        if self.accept_kw("extract"):
            self.expect_op("(")
            field = self.date_unit()
            if not (self.cur.kind == "kw" and self.cur.text == "from"):
                self.fail("expected FROM in EXTRACT")
            self.advance()
            e = self.expr()
            self.expect_op(")")
            return SExtract(field, e)
        if t.kind == "name":
            self.advance()
            if self.accept_op("("):  # function call
                return self.func_call(t.text.lower())
            if self.accept_op("."):
                name = self.identifier("column name")
                return SCol(t.text, name)
            return SCol(None, t.text)
        self.fail("expected an expression")

    def func_call(self, name: str):
        if self.accept_op("*"):
            self.expect_op(")")
            if name != "count":
                raise SqlError(f"{name.upper()}(*) is not supported")
            return SFunc("count", (SStar(),))
        distinct = self.accept_kw("distinct")
        args = []
        if not self.accept_op(")"):
            args.append(self.expr())
            while self.accept_op(","):
                args.append(self.expr())
            self.expect_op(")")
        return SFunc(name, tuple(args), distinct)

    def case_expr(self):
        whens = []
        while self.accept_kw("when"):
            cond = self.expr()
            self.expect_kw("then")
            whens.append((cond, self.expr()))
        if not whens:
            self.fail("CASE requires at least one WHEN")
        default = SLit(None)
        if self.accept_kw("else"):
            default = self.expr()
        self.expect_kw("end")
        return SCase(tuple(whens), default)


def _unquote(s: str) -> str:
    return s[1:-1].replace("''", "'")


def parse(sql: str) -> Select:
    """Parse a SELECT statement into the statement AST."""
    return _Parser(sql).parse()


# ----------------------------------------------------------------------
# expression utilities shared by planner/optimizer
# ----------------------------------------------------------------------
def split_conjuncts(e):
    """Flatten nested ANDs into a list of conjuncts."""
    if isinstance(e, SAnd):
        return split_conjuncts(e.a) + split_conjuncts(e.b)
    return [e]


def conjoin(parts):
    out = None
    for p in parts:
        out = p if out is None else SAnd(out, p)
    return out


def walk(e):
    """Yield every node of an expression tree (pre-order)."""
    yield e
    for f in dataclasses.fields(e):
        v = getattr(e, f.name)
        if dataclasses.is_dataclass(v):
            yield from walk(v)
        elif isinstance(v, tuple):
            for item in v:
                if dataclasses.is_dataclass(item):
                    yield from walk(item)
                elif isinstance(item, tuple):  # SCase whens
                    for sub in item:
                        if dataclasses.is_dataclass(sub):
                            yield from walk(sub)


def expr_columns(e):
    """Set of internal column names referenced by an expression."""
    return {n.internal for n in walk(e) if isinstance(n, SCol)}


def _transform_item(x, fn):
    if dataclasses.is_dataclass(x):
        return transform(x, fn)
    if isinstance(x, tuple):
        return tuple(_transform_item(s, fn) for s in x)
    return x


def transform(e, fn):
    """Bottom-up rewrite: apply ``fn`` to every node, children first."""
    if not dataclasses.is_dataclass(e):
        return e
    changes = {}
    for f in dataclasses.fields(e):
        v = getattr(e, f.name)
        nv = _transform_item(v, fn)
        if nv != v:
            changes[f.name] = nv
    if changes:
        e = dataclasses.replace(e, **changes)
    return fn(e)


def _sql_str(s: str) -> str:
    return "'" + s.replace("'", "''") + "'"


def format_expr(e) -> str:
    """SQL rendering for explain(); simple expressions re-parse to an
    equal AST (see the round-trip tests)."""
    if isinstance(e, SCol):
        return e.internal
    if isinstance(e, SLit):
        return _sql_str(e.value) if isinstance(e.value, str) else str(e.value)
    if isinstance(e, SDate):
        return f"DATE '{e.text}'"
    if isinstance(e, SInterval):
        return f"INTERVAL '{e.days}' DAY"
    if isinstance(e, SBin):
        return f"({format_expr(e.a)} {e.op} {format_expr(e.b)})"
    if isinstance(e, SCmp):
        return f"({format_expr(e.a)} {e.op} {format_expr(e.b)})"
    if isinstance(e, SAnd):
        return f"({format_expr(e.a)} AND {format_expr(e.b)})"
    if isinstance(e, SOr):
        return f"({format_expr(e.a)} OR {format_expr(e.b)})"
    if isinstance(e, SNot):
        return f"(NOT {format_expr(e.a)})"
    if isinstance(e, SIn):
        vals = ", ".join(format_expr(v) for v in e.values)
        return f"({format_expr(e.e)} {'NOT ' if e.negated else ''}IN ({vals}))"
    if isinstance(e, SBetween):
        return (
            f"({format_expr(e.e)} {'NOT ' if e.negated else ''}BETWEEN "
            f"{format_expr(e.lo)} AND {format_expr(e.hi)})"
        )
    if isinstance(e, SLike):
        pat = _sql_str(e.pattern)
        return f"({format_expr(e.e)} {'NOT ' if e.negated else ''}LIKE {pat})"
    if isinstance(e, SIsNull):
        return f"({format_expr(e.e)} IS {'NOT ' if e.negated else ''}NULL)"
    if isinstance(e, SCase):
        parts = " ".join(
            f"WHEN {format_expr(c)} THEN {format_expr(r)}" for c, r in e.whens
        )
        tail = "" if e.default == SLit(None) else f" ELSE {format_expr(e.default)}"
        return f"CASE {parts}{tail} END"
    if isinstance(e, SExtract):
        return f"EXTRACT({e.field.upper()} FROM {format_expr(e.e)})"
    if isinstance(e, SFunc):
        inner = ", ".join(
            "*" if isinstance(a, SStar) else format_expr(a) for a in e.args
        )
        d = "DISTINCT " if e.distinct else ""
        return f"{e.name.upper()}({d}{inner})"
    if isinstance(e, SStar):
        return "*"
    if isinstance(e, SSub):
        return f"({format_select(e.select.v)})"
    if isinstance(e, SInSub):
        neg = "NOT " if e.negated else ""
        return f"({format_expr(e.e)} {neg}IN ({format_select(e.select.v)}))"
    if isinstance(e, SExists):
        neg = "NOT " if e.negated else ""
        return f"({neg}EXISTS ({format_select(e.select.v)}))"
    if hasattr(e, "render"):  # planned subquery markers (plan.py)
        return e.render()
    return repr(e)


def format_select(sel: Select) -> str:
    """Render a statement AST back to SQL text (single line).

    The output re-parses to an equal AST, which the round-trip tests
    rely on; it is also used by explain() for unplanned subqueries."""
    cols = ", ".join(
        ("*" if isinstance(e, SStar) else format_expr(e))
        + (f" AS {a}" if a else "")
        for e, a in sel.columns
    )
    def item_sql(it: FromItem) -> str:
        if it.sub is not None:
            return f"({format_select(it.sub.v)}) AS {it.alias}"
        if it.alias != it.table:
            return f"{it.table} {it.alias}"
        return it.table

    items = ", ".join(item_sql(it) for it in sel.from_items)
    out = f"SELECT {'DISTINCT ' if sel.distinct else ''}{cols} FROM {items}"
    for jc in sel.joins:
        out += (
            f" {jc.how.upper()} JOIN {item_sql(jc.item)} "
            f"ON {format_expr(jc.on)}"
        )
    if sel.where is not None:
        out += f" WHERE {format_expr(sel.where)}"
    if sel.group_by:
        out += " GROUP BY " + ", ".join(format_expr(g) for g in sel.group_by)
    if sel.having is not None:
        out += f" HAVING {format_expr(sel.having)}"
    if sel.order_by:
        out += " ORDER BY " + ", ".join(
            f"{format_expr(e)} {'ASC' if asc else 'DESC'}" for e, asc in sel.order_by
        )
    if sel.limit is not None:
        out += f" LIMIT {sel.limit}"
    return out
