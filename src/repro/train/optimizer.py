"""Optimizers: AdamW (fp32 master + moments) and memory-lean Adafactor
(factored second moment, no first moment, updates bf16 params in
place) — the latter is what lets the 1T kimi-k2 config fit 512 v5e
chips (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params) -> (params, state)
    state_specs: Callable[[Any, Any], Any]  # (param_specs, params_shape) -> specs


# ----------------------------------------------------------------------
# AdamW
# ----------------------------------------------------------------------
def adamw(
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
        zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
        return {
            "m": zeros(params),
            "v": zeros(params),
            "master": f32(params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        b1t = 1 - b1 ** step.astype(jnp.float32)
        b2t = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, master):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            u = (m2 / b1t) / (jnp.sqrt(v2 / b2t) + eps)
            master2 = master - lr * (u + weight_decay * master)
            return m2, v2, master2

        out = jax.tree.map(upd, grads, state["m"], state["v"], state["master"])
        m2 = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        v2 = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        master2 = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(
            lambda mstr, p: mstr.astype(p.dtype), master2, params
        )
        return new_params, {"m": m2, "v": v2, "master": master2, "step": step}

    def state_specs(param_specs, params_shape):
        return {
            "m": param_specs,
            "v": param_specs,
            "master": param_specs,
            "step": P(),
        }

    return Optimizer(init, update, state_specs)


# ----------------------------------------------------------------------
# Adafactor (factored second moment, beta1=0, no master copy)
# ----------------------------------------------------------------------
def adafactor(lr: float = 1e-3, eps: float = 1e-30, clip: float = 1.0,
              decay: float = 0.8) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def leaf(x):
            if _factored(x.shape):
                return {
                    "vr": jnp.zeros(x.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(x.shape, jnp.float32)}

        return {
            "moments": jax.tree.map(leaf, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, mom, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(g.shape):
                vr = beta * mom["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * mom["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.clip(vr.mean(axis=-1, keepdims=True), 1e-30)
                vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
                u = g / jnp.sqrt(vhat + eps)
                mom2 = {"vr": vr, "vc": vc}
            else:
                v = beta * mom["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(v + eps)
                mom2 = {"v": v}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip)
            p2 = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return p2, mom2

        flat, tdef = jax.tree.flatten(params)
        gflat = tdef.flatten_up_to(grads)
        mflat = tdef.flatten_up_to(state["moments"])
        out = [upd(g, m, p) for g, m, p in zip(gflat, mflat, flat)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_moments = tdef.unflatten([o[1] for o in out])
        return new_params, {"moments": new_moments, "step": step}

    def state_specs(param_specs, params_shape):
        def leaf_spec(spec, shape):
            if _factored(shape.shape):
                return {
                    "vr": P(*spec[: len(shape.shape) - 1]),
                    "vc": P(*(list(spec[: len(shape.shape) - 2]) + [spec[len(shape.shape) - 1]]))
                    if len(spec) >= len(shape.shape)
                    else P(),
                }
            return {"v": P(*spec)}

        def norm_spec(spec, shape):
            s = tuple(spec) + (None,) * (len(shape.shape) - len(tuple(spec)))
            return leaf_spec(s, shape)

        return {
            "moments": jax.tree.map(
                norm_spec, param_specs, params_shape,
                is_leaf=lambda x: isinstance(x, P),
            ),
            "step": P(),
        }

    return Optimizer(init, update, state_specs)


def get_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return adamw()
    if name == "adafactor":
        return adafactor()
    raise KeyError(name)
