"""The pjit-able training step: microbatched gradient accumulation
(structured so XLA overlaps the grads' reduce-scatter of microbatch i
with the compute of microbatch i+1), optimizer apply, loss metrics.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from .optimizer import get_optimizer


def init_train_state(cfg: ModelConfig, key) -> Dict:
    params = lm.init_params(cfg, key)
    opt = get_optimizer(cfg.optimizer)
    return {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig):
    opt = get_optimizer(cfg.optimizer)
    gdt = jnp.dtype(cfg.grad_dtype)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]
        n_micro = cfg.microbatches

        def split_mb(x):
            return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])

        mb = jax.tree.map(split_mb, batch)

        def micro(g_acc, b):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: lm.loss_fn(cfg, p, b), has_aux=True
            )(params)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(gdt), g_acc, grads
            )
            return g_acc, loss

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
        g_sum, losses = jax.lax.scan(micro, g0, mb)
        grads = jax.tree.map(lambda g: (g / n_micro).astype(jnp.float32), g_sum)
        new_params, new_opt = opt.update(grads, state["opt"], params)
        metrics = {
            "loss": losses.mean(),
            "grad_norm": jnp.sqrt(
                sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
            ),
        }
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    return train_step
