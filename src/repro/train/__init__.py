"""Training runtime: optimizers, grad-accumulation step, sharded
checkpointing with elastic resharding, fault-tolerant loop."""
