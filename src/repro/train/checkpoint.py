"""Sharded checkpointing with elastic restore.

Save layout: one directory per step with a JSON manifest (tree
structure, shapes, dtypes) and one .npy per leaf — in a real multi-host
deployment each host writes only its addressable shards (the manifest
records the logical shape, so the restore path below is unchanged);
on this single-host container the full leaf is written.

Restore is *elastic*: arrays are loaded on host and device_put against
the CURRENT mesh's shardings, so a run checkpointed on one mesh resumes
on a different mesh/chip-count (the node-failure / re-scale story).

Writes are atomic (tmp dir + rename) so a preemption mid-save never
corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import numpy as np
import jax


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out[key] = leaf
    return out, jax.tree_util.tree_structure(tree)


def save(state: Any, directory: str, step: int) -> str:
    tmp = os.path.join(directory, f".tmp-{step}")
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(state)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = re.sub(r"[^\w\-]", "_", key) + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_")
    ]
    return max(steps) if steps else None


def restore(
    directory: str,
    like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Any:
    """Load into the structure of `like`; if `shardings` (a pytree of
    NamedSharding built from the CURRENT mesh) is given, leaves are
    device_put with it — elastic resharding."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, _ = _flatten(like)
    flat_sh, _ = _flatten(shardings) if shardings is not None else ({}, None)
    loaded = {}
    for key, leaf in flat_like.items():
        entry = manifest["leaves"][key]
        arr = np.load(os.path.join(d, entry["file"]))
        assert list(arr.shape) == list(leaf.shape), (key, arr.shape, leaf.shape)
        if key in flat_sh:
            loaded[key] = jax.device_put(arr.astype(leaf.dtype), flat_sh[key])
        else:
            loaded[key] = jax.numpy.asarray(arr.astype(leaf.dtype))
    # rebuild tree in `like`'s structure
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for path, _ in leaves_paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        ordered.append(loaded[key])
    return jax.tree_util.tree_unflatten(treedef, ordered)


def prune(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory) if d.startswith("step_")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
