"""Fault-tolerant training loop.

Features (each unit-tested at small scale):
- crash recovery: restores the latest checkpoint on start;
- periodic + preemption-signal-triggered atomic checkpoints (SIGTERM);
- bounded retry of transient step failures (simulated node flake);
- straggler mitigation: the data iterator is wrapped in a prefetch
  thread with a per-batch deadline — a slow shard is skipped (its batch
  replaced by the prefetched spare) and logged, instead of stalling the
  step (the skip-slow-host strategy).
"""
from __future__ import annotations

import logging
import queue
import signal
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax

from . import checkpoint

log = logging.getLogger("repro.train")


class PrefetchIterator:
    """Background-thread prefetch with a per-batch deadline."""

    def __init__(self, it: Iterator, depth: int = 2, deadline_s: float = 30.0):
        self._it = it
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._deadline = deadline_s
        self._spare = None
        self.skipped = 0
        self._done = False
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._done = True
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        try:
            item = self._q.get(timeout=self._deadline)
        except queue.Empty:
            if self._spare is not None:
                self.skipped += 1
                log.warning("data deadline exceeded; reusing spare batch (straggler skip)")
                return self._spare
            raise StopIteration from None
        if item is None:
            raise StopIteration
        self._spare = item
        return item


class TrainLoop:
    def __init__(
        self,
        train_step: Callable,
        state: Any,
        data: Iterator,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 50,
        max_step_retries: int = 2,
        state_shardings: Any = None,
        deadline_s: float = 30.0,
        fault_hook: Optional[Callable[[int], None]] = None,  # test injection
    ):
        self.train_step = train_step
        self.state = state
        self.data = PrefetchIterator(iter(data), deadline_s=deadline_s)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_step_retries = max_step_retries
        self.state_shardings = state_shardings
        self.fault_hook = fault_hook
        self.metrics_history = []
        self._preempted = False

    # ---- fault tolerance plumbing ----
    def install_signal_handler(self, sig=signal.SIGTERM):
        def handler(signum, frame):
            log.warning("preemption signal received; checkpointing at next step")
            self._preempted = True

        signal.signal(sig, handler)

    def maybe_restore(self):
        if self.ckpt_dir and checkpoint.latest_step(self.ckpt_dir) is not None:
            step = checkpoint.latest_step(self.ckpt_dir)
            log.info("restoring checkpoint step %s", step)
            self.state = checkpoint.restore(
                self.ckpt_dir, self.state, step=step, shardings=self.state_shardings
            )
            return step
        return None

    def _checkpoint(self):
        if self.ckpt_dir:
            step = int(jax.device_get(self.state["step"]))
            checkpoint.save(self.state, self.ckpt_dir, step)
            checkpoint.prune(self.ckpt_dir)

    # ---- the loop ----
    def run(self, num_steps: int) -> Dict:
        self.maybe_restore()
        start = int(jax.device_get(self.state["step"]))
        for i, batch in enumerate(self.data):
            step_no = start + i
            if step_no >= num_steps:
                break
            attempt = 0
            while True:
                try:
                    if self.fault_hook:
                        self.fault_hook(step_no)
                    self.state, metrics = self.train_step(self.state, batch)
                    jax.block_until_ready(metrics["loss"])
                    break
                except (jax.errors.JaxRuntimeError, RuntimeError) as e:
                    attempt += 1
                    if attempt > self.max_step_retries:
                        log.error("step %s failed %s times; checkpoint + raise", step_no, attempt)
                        self._checkpoint()
                        raise
                    log.warning("step %s attempt %s failed (%s); retrying", step_no, attempt, e)
            self.metrics_history.append(
                {k: float(jax.device_get(v)) for k, v in metrics.items()}
            )
            if self._preempted or (self.ckpt_every and (step_no + 1) % self.ckpt_every == 0):
                self._checkpoint()
                if self._preempted:
                    log.warning("exiting after preemption checkpoint")
                    break
        else:
            pass
        if self.ckpt_dir:
            self._checkpoint()
        return {
            "final_step": int(jax.device_get(self.state["step"])),
            "stragglers_skipped": self.data.skipped,
            "metrics": self.metrics_history,
        }
