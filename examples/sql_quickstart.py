"""SQL front-end quickstart: query TensorFrames with plain SELECTs.

    PYTHONPATH=src python examples/sql_quickstart.py
"""
import numpy as np

from repro import sql
from repro.core import TensorFrame
from repro.queries import scope

# ----------------------------------------------------------------------
# 1. ad-hoc frames: the scope is just a dict of tables
# ----------------------------------------------------------------------
frames = {
    "orders": TensorFrame.from_arrays(
        {
            "order_id": np.arange(8),
            "customer": np.array(
                ["ada", "bob", "ada", "cyd", "bob", "ada", "cyd", "bob"],
                dtype=object,
            ),
            "amount": np.array([10.0, 20.0, 35.0, 5.0, 60.0, 12.0, 44.0, 3.0]),
            "placed": np.array(
                ["2024-01-05", "2024-01-07", "2024-02-01", "2024-02-03",
                 "2024-02-11", "2024-03-02", "2024-03-09", "2024-03-15"],
                dtype="datetime64[D]",
            ),
        }
    ),
    "customers": TensorFrame.from_arrays(
        {
            "name": np.array(["ada", "bob", "cyd"], dtype=object),
            "region": np.array(["north", "south", "north"], dtype=object),
        }
    ),
}

query = """
    SELECT region,
           EXTRACT(MONTH FROM placed) AS month,
           COUNT(*) AS orders,
           SUM(amount) AS total
    FROM orders, customers
    WHERE customer = name AND amount BETWEEN 5 AND 50
    GROUP BY region, month
    HAVING SUM(amount) > 10
    ORDER BY region, month
"""

print(sql.execute(query, frames).show())

# ----------------------------------------------------------------------
# 2. explain(): pre- vs post-optimization plans
# ----------------------------------------------------------------------
print()
print(sql.explain(query, frames))

# ----------------------------------------------------------------------
# 3. subqueries: the optimizer decorrelates them into joins
# ----------------------------------------------------------------------
big_spenders = """
    SELECT customer, COUNT(*) AS n
    FROM orders o
    WHERE amount > (SELECT AVG(o2.amount) FROM orders o2)
      AND EXISTS (SELECT * FROM customers c
                  WHERE c.name = o.customer AND c.region = 'north')
    GROUP BY customer
    ORDER BY customer
"""
print()
print(sql.execute(big_spenders, frames).show())
# the naive plan keeps interpreted subquery markers; the optimized one
# shows the AttachScalar constant and the EXISTS rewritten to a semi join
print()
print(sql.explain(big_spenders, frames))

# ----------------------------------------------------------------------
# 4. registered scopes: benchmark tables by name
# ----------------------------------------------------------------------
tpch = scope("tpch", sf=0.001, seed=0)
top = sql.execute(
    """
    SELECT l_returnflag, l_linestatus, SUM(l_quantity) AS sum_qty
    FROM lineitem
    WHERE l_shipdate <= DATE '1998-12-01' - INTERVAL '90' DAY
    GROUP BY l_returnflag, l_linestatus
    ORDER BY l_returnflag, l_linestatus
    """,
    tpch,
)
print()
print(top.show())
