"""Run the full TPC-H suite on generated data and print a Fig.6-style
relative-time table (TensorFrame vs the row-at-a-time reference).

    PYTHONPATH=src python examples/tpch_analytics.py [--sf 0.01]
"""
import argparse
import time

from repro.data import tpch
from repro.queries import tpch_frames as QF
from repro.queries import tpch_numpy as QN


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument("--row-engine", action="store_true", help="also time the row-python reference")
    args = ap.parse_args()

    tables = tpch.generate(sf=args.sf, seed=0)
    frames = tpch.as_frames(tables)
    print(f"TPC-H sf={args.sf}: lineitem={tables['lineitem']['l_orderkey'].shape[0]} rows\n")
    print(f"{'query':6s} {'tensorframe':>12s} {'rowpython':>12s} {'speedup':>8s}")
    for i in range(1, 23):
        q = f"q{i}"
        fn = QF.ALL[q]
        fn(frames, sf=args.sf)  # warm
        t0 = time.perf_counter()
        fn(frames, sf=args.sf)
        tf = time.perf_counter() - t0
        if args.row_engine:
            t0 = time.perf_counter()
            QN.ALL[q](tables, sf=args.sf)
            tr = time.perf_counter() - t0
            print(f"{q:6s} {tf*1e3:10.1f}ms {tr*1e3:10.1f}ms {tr/tf:7.1f}x")
        else:
            print(f"{q:6s} {tf*1e3:10.1f}ms {'-':>12s} {'-':>8s}")


if __name__ == "__main__":
    main()
