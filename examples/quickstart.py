"""Quickstart: the TensorFrame public API — MojoFrame's Fig. 5 workflow
(filter / join / group-by, trait-based stateless UDFs), in JAX.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import TensorFrame, col, d


def main():
    rng = np.random.default_rng(0)
    n = 10_000
    orders = TensorFrame.from_arrays(
        {
            "order_id": np.arange(n, dtype=np.int64),
            "cust_id": rng.integers(0, 800, n),
            "price": np.round(rng.uniform(5, 500, n), 2),
            "status": rng.choice(["open", "shipped", "returned"], n).astype(object),
            "odate": np.datetime64("1995-01-01") + rng.integers(0, 900, n).astype("timedelta64[D]"),
            "comment": np.array(
                [f"note {i}: " + ("special packages requests" if i % 97 == 0 else "regular deposit")
                 for i in range(n)], dtype=object),
        }
    )
    customers = TensorFrame.from_arrays(
        {
            "cust_id": np.arange(800, dtype=np.int64),
            "segment": rng.choice(["BUILDING", "MACHINERY", "HOUSEHOLD"], 800).astype(object),
            "balance": np.round(rng.uniform(-100, 5000, 800), 2),
        }
    )
    print(orders)
    print(customers)

    # trait-based stateless filtering (paper §IV-A): composable exprs,
    # including the Q13-style ordered-substring UDF — no row loops
    hot = orders.filter(
        (col("status") != "returned")
        & (col("odate") >= d("1996-01-01"))
        & col("comment").str.not_exists_before("special", "requests")
        & (col("price") > 50.0)
    )
    print(f"\nfiltered: {hot.nrows} rows")

    # factorize-then-join (paper §IV-C): dense-code direct-address probe
    j = hot.join(customers, on="cust_id")

    # transposed composite-key group-by (paper §IV-B) + sort
    top = (
        j.groupby(["segment"])
        .agg([("revenue", "sum", "price"), ("orders", "size", ""), ("avg_bal", "mean", "balance")])
        .sort_values("revenue", ascending=False)
    )
    print("\nrevenue by segment:")
    print(top.show())


if __name__ == "__main__":
    main()
