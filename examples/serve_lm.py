"""Batched serving example: continuous-batching decode over a pool of
requests (slots refill as requests finish).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np
import jax

from repro.configs import get
from repro.models import lm
from repro.models.config import reduced
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = reduced(get("phi3-mini-3.8b"), n_layers=4, d_model=128, n_heads=4,
                  n_kv_heads=4, head_dim=32, d_ff=256, vocab=2048)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=4, max_len=128)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, rng.integers(3, 10)).astype(np.int32),
                max_new=rng.integers(4, 12))
        for i in range(12)
    ]
    t0 = time.time()
    eng.run(reqs, max_steps=600)
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens in {dt:.1f}s "
          f"({eng.steps} decode steps over 4 slots)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
