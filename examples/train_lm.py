"""End-to-end driver: curate a corpus with the TensorFrame relational
engine, then train a ~100M-parameter qwen3-family model for a few
hundred steps on CPU with checkpointing + fault tolerance.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.data import tokens as tok
from repro.models.config import reduced
from repro.train.loop import TrainLoop
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: d_model 512, 8 layers, vocab 32k
    cfg = reduced(
        get("qwen3-14b"),
        n_layers=10, d_model=640, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab=32064, microbatches=2, q_chunk=256,
    )
    n = cfg.param_count()
    print(f"model: {cfg.name}-reduced, {n/1e6:.1f}M params")

    corpus = tok.synthetic_corpus(4000, seed=1)
    doc_ids, weights = tok.curate(corpus, mixture={"web": 1.0, "books": 2.0, "wiki": 1.5, "code": 1.0})
    print(f"TensorFrame curation: {len(doc_ids)} docs survive filter+dedup")

    B, S = 8, 128
    data = (
        {k: jnp.asarray(v) for k, v in b.items()}
        for b in tok.token_batches(doc_ids, weights, cfg.vocab, B, S, steps=args.steps + 2)
    )
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    loop = TrainLoop(step, state, data, ckpt_dir=args.ckpt_dir, ckpt_every=100)
    loop.install_signal_handler()
    t0 = time.time()
    out = loop.run(args.steps)
    dt = time.time() - t0
    losses = [m["loss"] for m in out["metrics"]]
    print(f"steps={out['final_step']} in {dt:.0f}s ({B*S*len(losses)/dt:.0f} tok/s)")
    print(f"loss: {losses[0]:.3f} -> {min(losses):.3f}")
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
