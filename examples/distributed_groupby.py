"""Distributed relational ops: hash-repartitioned group-by and
broadcast semi-join over an 8-way data-parallel mesh (forced host
devices; on a real cluster this is the multi-pod path).

    PYTHONPATH=src python examples/distributed_groupby.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def main():
    from repro.dist.dframe import dist_groupby_sum, dist_repartition_by_key, dist_semi_join_mask

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    n, domain = 1 << 16, 256
    keys = jnp.asarray(rng.integers(0, domain, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))

    sums = dist_groupby_sum(mesh, keys, vals, domain)
    check = np.zeros(domain, np.float32)
    np.add.at(check, np.asarray(keys), np.asarray(vals))
    err = float(np.abs(np.asarray(sums) - check).max())
    print(f"dist group-by sum over {mesh.shape}: n={n} domain={domain} max_err={err:.2e}")

    build = jnp.asarray(rng.choice(np.arange(1024), 128, replace=False).astype(np.int32))
    probe = jnp.asarray(rng.integers(0, 1024, n).astype(np.int32))
    mask = dist_semi_join_mask(mesh, probe, build)
    print(f"broadcast semi-join: {int(np.asarray(mask).sum())} of {n} rows matched")

    k2, v2, valid, dropped = dist_repartition_by_key(mesh, keys, vals, capacity=n)
    print(f"full shuffle: rows preserved={int(np.asarray(valid).sum())}/{n} dropped={int(dropped)}")


if __name__ == "__main__":
    main()
